"""Serving launcher: batched generation with per-phase power capping.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --requests 8 --new 16
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import reduced as reduce_cfg
from repro.configs.registry import ARCH_IDS, get_model_config, get_run_config
from repro.models import lm
from repro.models.layers import Ctx
from repro.models.params import init_params
from repro.serving.engine import Request, ServeEngine
from repro.sharding import RULE_SETS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_model_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode path")
    run = get_run_config(args.arch, remat="none", logits_chunk=64)
    ctx = Ctx(run, RULE_SETS[run.serve_rules_name], None)
    params = init_params(lm.model_decls(cfg), jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, run, ctx, params,
                         batch_size=args.batch_size, max_seq=args.max_seq)
    reqs = [Request(uid=i, prompt=[(5 * i + j) % cfg.vocab
                                   for j in range(4 + i % 5)],
                    max_new_tokens=args.new)
            for i in range(args.requests)]
    done = engine.generate(reqs)
    for r in done:
        print(f"req {r.uid}: {len(r.generated)} tokens -> "
              f"{r.generated[:8]}{'...' if len(r.generated) > 8 else ''}")


if __name__ == "__main__":
    main()
