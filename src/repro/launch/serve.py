"""Serving launcher: batched generation with per-phase power capping.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --requests 8 --new 16

The engine runs prefill and decode under distinct phase caps from a
``repro.power.PowerManager`` (compute-bound prefill stays near max;
memory-bound decode drops low), and the modeled energy ledger is printed
after the batch drains.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import reduced as reduce_cfg
from repro.configs.registry import ARCH_IDS, get_model_config, get_run_config
from repro.models import lm
from repro.models.layers import Ctx
from repro.models.params import init_params
from repro.power import PowerManager, available_metrics
from repro.serving.engine import Request, ServeEngine, serve_phase_tasks
from repro.sharding import RULE_SETS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="device-resident decode tokens per host sync AND "
                         "per power-phase entry (chunk-amortized observe)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="max power-of-two prompt chunk per prefill step")
    ap.add_argument("--power-metric", default="sed",
                    choices=available_metrics())
    args = ap.parse_args()

    cfg = get_model_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode path")
    run = get_run_config(args.arch, remat="none", logits_chunk=64)
    ctx = Ctx(run, RULE_SETS[run.serve_rules_name], None)
    params = init_params(lm.model_decls(cfg), jax.random.PRNGKey(0))

    # phase caps for the FULL arch at production serving scale; the engine
    # below drives the same phases on the reduced model
    full = get_model_config(args.arch)
    pm = PowerManager(
        tasks=serve_phase_tasks(full, batch=128, prompt=32768,
                                new_tokens=args.new, chips=256),
        metric=args.power_metric)
    print(f"[caps:{args.power_metric}] "
          f"{ {k: round(v) for k, v in pm.schedule.caps.items()} }")

    engine = ServeEngine(cfg, run, ctx, params, batch_size=args.batch_size,
                         max_seq=args.max_seq, power=pm,
                         prefill_chunk=args.prefill_chunk,
                         decode_chunk=args.decode_chunk)
    reqs = [Request(uid=i, prompt=[(5 * i + j) % cfg.vocab
                                   for j in range(4 + i % 5)],
                    max_new_tokens=args.new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.generate(reqs)
    wall = time.perf_counter() - t0
    for r in done:
        print(f"req {r.uid}: {len(r.generated)} tokens -> "
              f"{r.generated[:8]}{'...' if len(r.generated) > 8 else ''}")
    n_tok = sum(len(r.generated) for r in done)
    print(f"[throughput] {n_tok} tokens in {wall:.2f}s "
          f"({n_tok / wall:.1f} tok/s, {engine.sync_count} host syncs)")
    e = pm.account_step()
    dt, de = pm.overhead_totals()
    print(f"[energy] modeled step {e['energy_j']:.1f}J "
          f"(-{e['energy_saving_pct']:.1f}% vs uncapped); "
          f"{pm.transitions} cap writes ({de*1e3:.1f} mJ overhead)")


if __name__ == "__main__":
    main()
