"""Faithful reproduction driver: the paper's LSMS study, end to end.

  PYTHONPATH=src python examples/lsms_scf.py [--plots]

1. Runs the REAL (miniature) KKR/SCF math in JAX — build KKR matrix, zgemm,
   LU solve, host density mixing — to demonstrate the workload itself.
2. Sweeps the paper-calibrated task mix over the 9-setting cap sweep with
   the analytic GH200-style power-steering model.
3. Prints the paper's artifacts: Table 1 (task profile), Fig 2 (SED), Fig 3
   (ED), Table 2 (optimal caps + deltas, aggregations).
4. --plots writes fig1/fig2/fig3 PNGs to artifacts/figs/.
"""

from __future__ import annotations

import argparse
import os
import time

import jax

from repro.core import (aggregate_table2, euclidean_distance, generate_trace,
                        speedup_energy_delay, table2,
                        weighted_application_impact)
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.models.lsms import (LsmsConfig, paper_calibrated_tasks, run_scf,
                               scf_phase_sequence)
from repro.power import PowerManager


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--plots", action="store_true")
    ap.add_argument("--atoms", type=int, default=8)
    args = ap.parse_args()

    # -- 1. the actual workload (miniature) --------------------------------
    t0 = time.perf_counter()
    density = run_scf(LsmsConfig(n_atoms=args.atoms), jax.random.PRNGKey(0))
    print(f"[scf] {args.atoms} atoms, 2 iterations, "
          f"{time.perf_counter()-t0:.1f}s, density[0:4]={density[:4]}")

    # -- 2. the power-cap sweep (the manager's backend runs it) ------------
    tasks = paper_calibrated_tasks()
    pm = PowerManager(tasks=tasks, metric="sed")
    table = pm.table

    # -- 3. paper artifacts -------------------------------------------------
    print("\n== Table 1: per-task profile at default power (no capping) ==")
    print(f"{'task':18s} {'time(s)':>8s} {'energy(J)':>10s} {'power(W)':>9s}")
    for r in table.table1():
        print(f"{r['task']:18s} {r['total_time_s']:8.2f} "
              f"{r['total_energy_j']:10.1f} {r['avg_power_w']:9.1f}")

    print("\n== Table 2: optimal caps per metric vs default ==")
    print(f"{'task':18s} {'SED(W)':>7s} {'ED(W)':>7s} "
          f"{'SED dE%':>8s} {'ED dE%':>8s} {'SED dt%':>8s} {'ED dt%':>8s}")
    for r in table2(table):
        print(f"{r.task:18s} {r.sed_cap:7.0f} {r.ed_cap:7.0f} "
              f"{r.sed_energy_reduction_pct:8.2f} "
              f"{r.ed_energy_reduction_pct:8.2f} "
              f"{r.sed_runtime_increase_pct:8.2f} "
              f"{r.ed_runtime_increase_pct:8.2f}")
    agg = aggregate_table2(table2(table))
    print(f"\naggregated (paper's 'ideal scenario' sums): "
          f"SED {agg['sed_energy_savings_pct_sum']:.0f}% energy / "
          f"{agg['sed_runtime_increase_pct_sum']:.0f}% runtime; "
          f"ED {agg['ed_energy_savings_pct_sum']:.0f}% / "
          f"{agg['ed_runtime_increase_pct_sum']:.0f}%")
    w = weighted_application_impact(table)
    print(f"weighted whole-app: SED -{w['sed_app_energy_reduction_pct']:.1f}% "
          f"energy @ +{w['sed_app_runtime_increase_pct']:.1f}% runtime; "
          f"ED -{w['ed_app_energy_reduction_pct']:.1f}% @ "
          f"+{w['ed_app_runtime_increase_pct']:.1f}%")

    e = pm.account_step()
    print(f"\nPowerManager session (SED schedule, dwell-filtered): "
          f"{e['energy_j']:.0f}J per pass "
          f"(-{e['energy_saving_pct']:.1f}% vs uncapped, "
          f"{e['transitions']} cap writes)")

    if args.plots:
        _plots(table, tasks)


def _plots(table, tasks) -> None:
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    os.makedirs("artifacts/figs", exist_ok=True)
    caps = sorted(table.caps())

    trace = generate_trace(scf_phase_sequence(),
                           cap=DEFAULT_SUPERCHIP.p_default, jitter_sigma=4.0)
    arr = trace.as_arrays()
    fig, ax = plt.subplots(figsize=(9, 3.2))
    ax.plot(arr["t"], arr["superchip"], lw=0.6, label="superchip")
    ax.plot(arr["t"], arr["chip"], lw=0.6, label="accelerator")
    ax.plot(arr["t"], arr["host"], lw=0.6, label="host")
    ax.set(xlabel="time (s)", ylabel="power (W)",
           title="Fig.1 analogue: power trace, 2 SCF iterations (5 ms)")
    ax.legend()
    fig.savefig("artifacts/figs/fig1_power_trace.png", dpi=130,
                bbox_inches="tight")

    for name, fn, better in (("fig2_sed", speedup_energy_delay, "higher"),
                             ("fig3_ed", euclidean_distance, "lower")):
        fig, ax = plt.subplots(figsize=(7, 4))
        for t in table.tasks():
            curve = fn(table, t)
            ax.plot(caps, [curve[c] for c in caps], marker="o", ms=3,
                    label=t)
        ax.set(xlabel="superchip power cap (W)",
               ylabel=name.split("_")[1].upper(),
               title=f"{name} per GPU task ({better} is better)")
        ax.legend(fontsize=7)
        fig.savefig(f"artifacts/figs/{name}.png", dpi=130,
                    bbox_inches="tight")
    print("plots written to artifacts/figs/")


if __name__ == "__main__":
    main()
