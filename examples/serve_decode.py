"""Batched serving demo: prefill + continuous decode with phase-level caps.

  PYTHONPATH=src python examples/serve_decode.py [--requests 6] [--new 8]

Prefill is compute-bound (cap near max per SED); decode is memory-bound
(KV-cache streaming — a low cap is nearly free): the engine reports the
modeled energy ledger for both phases, the serving analogue of the paper's
per-task capping.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import reduced
from repro.configs.registry import get_model_config, get_run_config
from repro.models import lm
from repro.models.layers import Ctx
from repro.models.params import init_params
from repro.power import PowerManager
from repro.serving.engine import Request, ServeEngine, serve_phase_tasks
from repro.sharding import RULE_SETS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(get_model_config(args.arch))
    run = get_run_config(args.arch, remat="none", logits_chunk=64)
    ctx = Ctx(run, RULE_SETS[run.rules_name], None)
    params = init_params(lm.model_decls(cfg), jax.random.PRNGKey(0))

    # per-phase capping for the FULL arch at production serving scale; the
    # engine runs prefill/decode under these caps via pm.phase(...)
    full = get_model_config(args.arch)
    tasks = serve_phase_tasks(full, batch=128, prompt=32768,
                              new_tokens=128, chips=256)
    pm = PowerManager(tasks=tasks, metric="sed")

    engine = ServeEngine(cfg, run, ctx, params, batch_size=4, max_seq=64,
                         power=pm)
    reqs = [Request(uid=i, prompt=[(7 * i + j) % cfg.vocab
                                   for j in range(5 + i % 3)],
                    max_new_tokens=args.new)
            for i in range(args.requests)]
    done = engine.generate(reqs)
    for r in done[:4]:
        print(f"req {r.uid}: prompt={r.prompt} -> generated={r.generated}")
    assert all(len(r.generated) == args.new for r in done)

    for metric in ("sed", "ed"):
        decisions = PowerManager(pm.table, metric=metric).decide()
        summary = {d.task: (round(d.cap),
                            f"-{d.energy_reduction_pct:.1f}%E",
                            f"+{d.runtime_increase_pct:.1f}%t")
                   for d in decisions}
        print(f"[{metric}] {summary}")
    print(f"[phases] {len(pm.history)} capped phase entries, "
          f"{pm.transitions} cap writes")
    print("serving demo done.")


if __name__ == "__main__":
    main()
