"""Quickstart: train a tiny LM with the energy-aware loop on CPU.

  PYTHONPATH=src python examples/quickstart.py [--steps 20] [--arch llama3.2-3b]

Demonstrates the public API end to end: config registry -> reduced model ->
data pipeline -> train step -> per-phase power-capping ledger (the paper's
technique applied to the training loop).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_model_config, get_run_config
from repro.data.pipeline import DataConfig, TokenSource
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.models.layers import Ctx
from repro.power import PowerManager, available_metrics
from repro.sharding import RULE_SETS
from repro.train.phases import training_phase_tasks
from repro.train.step import init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--power-metric", default="sed",
                    choices=available_metrics())
    args = ap.parse_args()

    cfg = reduced(get_model_config(args.arch))
    run = get_run_config(args.arch, remat="none", logits_chunk=64,
                         power_metric=args.power_metric, total_steps=args.steps)
    ctx = Ctx(run, RULE_SETS[run.rules_name], None)

    data = TokenSource(DataConfig(vocab=cfg.vocab, global_batch=8, seq_len=128))
    state = init_state(cfg, run, jax.random.PRNGKey(0))
    st = state.tree()
    step_fn = jax.jit(make_train_step(cfg, run, ctx))

    # the paper's technique: per-phase caps chosen by SED/ED over the
    # modeled (task x cap) table for this model's training phases.  The
    # ledger models the FULL arch at production scale (train_4k, 256 chips)
    # while the loop itself trains the reduced model on CPU.
    full = get_model_config(args.arch)
    tasks = training_phase_tasks(full, batch=256, seq=4096, chips=256)
    # 200 us dwell: one hwmon power-API write amortizes over phases >=200 us
    pm = PowerManager(tasks=tasks, metric=args.power_metric,
                      spec=DEFAULT_SUPERCHIP, min_dwell_s=2e-4)

    print(f"arch={cfg.name} params per-phase caps: "
          f"{ {k: round(v) for k, v in pm.schedule.caps.items()} }")
    for i in range(args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        st, metrics = step_fn(st, batch)
        dt = time.perf_counter() - t0
        stats = pm.account_step()
        print(f"step {i:3d} loss={float(metrics['loss']):.4f} "
              f"wall={dt*1e3:6.1f}ms modeled: E={stats['energy_j']:.2f}J "
              f"(saved {stats['energy_saving_pct']:.1f}% vs uncapped)")
    print("done.")


if __name__ == "__main__":
    main()
