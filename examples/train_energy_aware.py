"""End-to-end training driver: ~100M-parameter LM, energy-aware, fault-
tolerant.

  PYTHONPATH=src python examples/train_energy_aware.py \
      [--steps 300] [--size 100m|20m|tiny] [--ckpt-dir /tmp/ea_ckpt] \
      [--power-metric sed|ed] [--resume] [--kill-at N]

Demonstrates every production feature in one loop:
  * real config system (llama-family ~100M config) + deterministic data
  * jitted train step (scan layers, remat, chunked CE)
  * async checkpointing every --ckpt-every steps + EXACT resume
  * SIGTERM preemption guard (--kill-at simulates a preemption)
  * straggler watchdog (EWMA step-time monitor)
  * the paper's technique: per-phase power caps via SED/ED + energy ledger
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import signal
import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import DataConfig, TokenSource
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.models.layers import Ctx
from repro.power import PowerManager, available_metrics
from repro.runtime.supervisor import PreemptionGuard, StragglerWatchdog
from repro.sharding import RULE_SETS
from repro.train.phases import training_phase_tasks
from repro.train.step import init_state, make_train_step

SIZES = {
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab=32000),
    "20m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
                head_dim=64, d_ff=1024, vocab=8192),
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 head_dim=32, d_ff=256, vocab=512),
}


def build_config(size: str) -> ModelConfig:
    return ModelConfig(name=f"ea-{size}", family="dense",
                       mlp="swiglu", norm="rmsnorm", **SIZES[size])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/ea_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-at", type=int, default=-1,
                    help="send ourselves SIGTERM at this step (preemption demo)")
    ap.add_argument("--power-metric", default="sed",
                    choices=available_metrics())
    args = ap.parse_args()

    cfg = build_config(args.size)
    run = RunConfig(remat="none" if args.size == "tiny" else "full",
                    logits_chunk=min(args.seq, 512), total_steps=args.steps,
                    warmup_steps=max(args.steps // 10, 2),
                    power_metric=args.power_metric)
    ctx = Ctx(run, RULE_SETS[run.rules_name], None)

    data = TokenSource(DataConfig(vocab=cfg.vocab, global_batch=args.batch,
                                  seq_len=args.seq))
    step_fn = jax.jit(make_train_step(cfg, run, ctx))

    os.makedirs(args.ckpt_dir, exist_ok=True)
    state = init_state(cfg, run, jax.random.PRNGKey(0))
    st = state.tree()
    start = 0
    if args.resume and checkpoint.available_steps(args.ckpt_dir):
        st, start = checkpoint.restore(args.ckpt_dir, st)
        print(f"[resume] restored step {start}")

    # -- the paper's technique wired into the loop --------------------------
    tasks = training_phase_tasks(cfg, batch=args.batch, seq=args.seq)
    pm = PowerManager(tasks=tasks, metric=args.power_metric,
                      spec=DEFAULT_SUPERCHIP, min_dwell_s=2e-4)
    print(f"[caps:{args.power_metric}] "
          f"{ {k: round(v) for k, v in pm.schedule.caps.items()} }")

    watchdog = StragglerWatchdog()
    pending_ckpt = None
    with PreemptionGuard() as guard:
        for i in range(start, args.steps):
            if i == args.kill_at:
                os.kill(os.getpid(), signal.SIGTERM)
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            st, metrics = step_fn(st, batch)
            dt = time.perf_counter() - t0
            slow = watchdog.observe(i, dt)
            e = pm.account_step()
            if i % 5 == 0 or slow:
                print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                      f"wall={dt*1e3:7.1f}ms E={e['energy_j']:.3f}J "
                      f"(-{e['energy_saving_pct']:.1f}%)"
                      f"{'  [STRAGGLER]' if slow else ''}")
            if (i + 1) % args.ckpt_every == 0 or guard.should_stop:
                if pending_ckpt is not None:
                    pending_ckpt.join()
                pending_ckpt = checkpoint.save(
                    jax.device_get(st), i + 1, args.ckpt_dir, blocking=False)
            if guard.should_stop:
                if pending_ckpt is not None:
                    pending_ckpt.join()
                print(f"[preempted] checkpointed at step {i+1}; exiting 143")
                raise SystemExit(143)
    if pending_ckpt is not None:
        pending_ckpt.join()
    checkpoint.save(jax.device_get(st), args.steps, args.ckpt_dir)
    print(f"[done] {args.steps} steps; final loss "
          f"{float(metrics['loss']):.4f}; straggler events: "
          f"{len(watchdog.events)}")


if __name__ == "__main__":
    main()
