"""Paper Table 1: per-GPU-task profile at the default (uncapped) setting.

Reproduces: task ranking by total energy; zgemm dominant; buildKKRMatrix
second despite 169x fewer invocations; idle phases visible.
"""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import measure_sweep
from repro.models.lsms import paper_calibrated_tasks


def run() -> dict:
    tasks = paper_calibrated_tasks()

    def compute():
        return measure_sweep(tasks)

    table, us = timed(compute)
    rows = table.table1()
    emit("table1_total_energy_j", us,
         round(sum(r["total_energy_j"] for r in rows), 1))
    emit("table1_total_runtime_s", us,
         round(sum(r["total_time_s"] for r in rows), 2))
    emit("table1_top_task", us, rows[0]["task"])
    # paper: zgemm(ts64) consumes by far the most energy
    assert rows[0]["task"] == "zgemm_ts64", rows[0]
    # paper: buildKKRMatrix is 2nd despite only 128 calls
    assert rows[1]["task"] == "buildKKRMatrix", rows[1]
    return {"rows": rows}


if __name__ == "__main__":
    run()
