"""Paged KV cache + copy-on-write prefix sharing: the serving-memory
benchmark for PR "paged KV cache with CoW prefix sharing".

Runs the SAME request stream — every prompt opening with one shared
system-prefix — through three ``ServeEngine`` arms:

  dense    the per-slot ``(B, max_seq, ...)`` cache (baseline)
  paged    block-pool cache, no sharing (paging overhead in isolation)
  shared   paged + prefix registry: admissions after the first map the
           cached prefix blocks (CoW on the partial tail block) and
           prefill only their private suffix

and reports, per arm:

  * prefill tok/s   effective prefill throughput on a prefill-dominated
                    probe: prompt tokens admitted / MODELED prefill
                    seconds (the PowerManager roofline — the same basis
                    as J/token).  Skipped prefix rows are chunk programs
                    the arm never ran, so they cost no modeled time.
                    Wall-clock variants ride along in the JSON, but the
                    gate uses the modeled figure: at CPU-interpret toy
                    scale wall time is jit-dispatch noise, while the
                    roofline tracks what an accelerator would do.
  * tokens/s        generated-token throughput on the serving scenario
  * J/token         modeled prefill+decode energy per generated token
                    (prefill phases cost one call per CHUNK PROGRAM run,
                    so skipped prefix chunks are energy not spent)
  * HBM bytes/slot  resident cache footprint per slot (dense: the full
                    lane; paged: peak pool blocks actually mapped)
  * migration bytes a mid-run drain/restore round-trip's payload bytes
                    (prefix-shared slots ship only their private suffix)
  * prefix rows skipped / registry hits / CoW copies (shared arm)

Token streams are asserted BIT-IDENTICAL across all three arms, on the
straight runs and through the drain/restore round-trip.  Machine-readable
results go to ``BENCH_prefix.json``; ``--min-prefill-speedup`` (CI smoke)
fails loudly when shared/dense effective prefill throughput drops below
the threshold, and the shared arm must strictly shrink migration bytes.

  PYTHONPATH=src:. python benchmarks/prefix_sharing.py \
      [--requests 18] [--min-prefill-speedup 1.2] [--trace-out T.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from benchmarks.common import bench_meta, emit
from repro.configs.base import reduced
from repro.configs.registry import get_model_config, get_run_config
from repro.models import lm
from repro.models.layers import Ctx
from repro.models.params import init_params
from repro.obs import Tracer, dump_chrome_trace
from repro.power import PowerManager
from repro.serving.engine import Request, ServeEngine, serve_phase_tasks
from repro.sharding import RULE_SETS

ARCH = "llama3.2-3b"
MAX_SEQ = 64
BATCH = 4
BLOCK_SIZE = 8
PREFILL_CHUNK = 16
DECODE_CHUNK = 4
SEED = 0

#: The shared system prefix every prompt opens with — 33 tokens lands a
#: PARTIAL tail block (33 = 4 full blocks of 8 + 1 row), so the shared
#: arm exercises the copy-on-write pivot on every admission.
PREFIX_LEN = 33

ARMS = ("dense", "paged", "shared")


def _scenario(n_requests: int, max_new: int):
    """Shared 33-token prefix + per-request suffix (3..10 tokens)."""
    prefix = [(7 * j + 11) % 251 + 2 for j in range(PREFIX_LEN)]
    out = []
    for i in range(n_requests):
        slen = 3 + (5 * i) % 8
        suffix = [(13 * i + 3 * j + 1) % 251 + 2 for j in range(slen)]
        out.append((prefix + suffix, max_new))
    return out


def _requests(scenario):
    return [Request(uid=i, prompt=list(p), max_new_tokens=n,
                    prefix_len=PREFIX_LEN)
            for i, (p, n) in enumerate(scenario)]


def _build(kind: str, tracer=None):
    cfg = reduced(get_model_config(ARCH))
    run = get_run_config(ARCH, remat="none", logits_chunk=64)
    ctx = Ctx(run, RULE_SETS[run.serve_rules_name], None)
    params = init_params(lm.model_decls(cfg), jax.random.PRNGKey(SEED))
    pm = PowerManager(tasks=serve_phase_tasks(
        get_model_config(ARCH), batch=128, prompt=32768, new_tokens=16,
        chips=256))
    return ServeEngine(cfg, run, ctx, params, batch_size=BATCH,
                       max_seq=MAX_SEQ, power=pm,
                       prefill_chunk=PREFILL_CHUNK,
                       decode_chunk=DECODE_CHUNK,
                       paged=kind != "dense", block_size=BLOCK_SIZE,
                       prefix_sharing=kind == "shared", tracer=tracer)


def _rows_bytes_per_slot(eng) -> float:
    """Resident cache footprint one slot costs this engine: the full
    dense lane, or the pool blocks the arm actually mapped at peak."""
    spec = lm.cache_slot_spec(eng.cfg)
    rows = [leaf for key, kind in spec.items() if kind == lm.SLOT_ROWS
            for leaf in jax.tree.leaves(eng._cache[key])]
    total = sum(leaf.nbytes for leaf in rows)
    if not eng.paged:
        return total / eng.batch_size
    # pool leaves hold n_blocks + 1 physical blocks (the parking block
    # is bookkeeping, not per-slot capacity)
    per_block = total / (eng.n_blocks + 1)
    return per_block * eng.peak_used_blocks / eng.batch_size


def _streams(done) -> dict:
    return {r.uid: list(r.generated) for r in done}


def _modeled_phase_s(eng, name: str) -> float:
    """Summed modeled runtime of every ``name`` phase this engine ran
    (PhaseRecord history; runs here stay far below history_limit)."""
    return sum(r.modeled.runtime for r in eng.power.history
               if r.name == name and r.modeled is not None)


def _run_probe(kind: str, scenario) -> dict:
    """Prefill-dominated probe: modeled prefill time ~ chunk programs
    actually run, so skipped prefix rows show up as throughput."""
    eng = _build(kind)
    reqs = _requests(scenario)
    t0 = time.perf_counter()
    done = eng.generate(reqs)
    wall = time.perf_counter() - t0
    prompt_tokens = sum(len(p) for p, _ in scenario)
    prefill_s = _modeled_phase_s(eng, "prefill")
    return {"engine": eng, "streams": _streams(done), "wall_s": wall,
            "prefill_modeled_s": prefill_s,
            "prefill_tokens_per_s": prompt_tokens / prefill_s,
            "prefill_tokens_per_s_wall": prompt_tokens / wall}


def _run_serve(kind: str, scenario, tracer=None) -> dict:
    """Serving scenario with a mid-run drain/restore round-trip."""
    eng = _build(kind, tracer=tracer)
    t0 = time.perf_counter()
    eng.start(_requests(scenario))
    eng.step()                      # first wave mid-decode
    snaps = eng.drain()             # full drain: warm + cold snapshots
    migration_bytes = sum(s.payload_bytes for s in snaps)
    assert any(s.warm for s in snaps), "drain caught no warm slot"
    eng.restore(snaps)
    while eng.pending:
        eng.step()
    wall = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in eng.finished)
    energy = eng.power.modeled_energy_j
    return {
        "engine": eng,
        "streams": _streams(eng.finished),
        "wall_s": wall,
        "tokens": n_tok,
        "tokens_per_s": n_tok / wall,
        "j_per_token": energy / n_tok if n_tok else 0.0,
        "migration_bytes": migration_bytes,
        "hbm_bytes_per_slot": _rows_bytes_per_slot(eng),
    }


def run(n_requests: int = 18, min_prefill_speedup: float | None = None,
        json_path: str = "BENCH_prefix.json",
        trace_out: str | None = None) -> dict:
    probe_scn = _scenario(n_requests, max_new=2)
    serve_scn = _scenario(n_requests, max_new=8)
    results: dict = {}
    for kind in ARMS:
        # warmup off the clock: jit traces for every chunk size + decode
        _build(kind).generate(_requests(probe_scn[:2]))
        probe = _run_probe(kind, probe_scn)
        tracer = Tracer() if (trace_out and kind == "shared") else None
        serve = _run_serve(kind, serve_scn, tracer=tracer)
        eng = serve.pop("engine")
        peng = probe.pop("engine")
        results[kind] = {
            "prefill_tokens_per_s": probe["prefill_tokens_per_s"],
            "prefill_tokens_per_s_wall": probe["prefill_tokens_per_s_wall"],
            "prefill_modeled_s": probe["prefill_modeled_s"],
            **{k: v for k, v in serve.items() if k != "streams"},
            "prefill_tokens_skipped": (eng.prefill_tokens_skipped
                                       + peng.prefill_tokens_skipped),
            "cow_copies": eng.cow_copies + peng.cow_copies,
            "peak_used_blocks": max(eng.peak_used_blocks,
                                    peng.peak_used_blocks),
        }
        results[kind]["probe_streams"] = probe["streams"]
        results[kind]["serve_streams"] = serve["streams"]
        if tracer is not None:
            dump_chrome_trace(tracer, trace_out,
                              process_name="prefix-sharing")
            emit("prefix_trace_spans", 0.0, str(len(tracer.spans)))

    # BIT-IDENTITY: all arms, both scenarios, through drain/restore
    for kind in ("paged", "shared"):
        for which in ("probe_streams", "serve_streams"):
            assert results[kind][which] == results["dense"][which], (
                f"{kind} {which} diverged from dense — paging broke "
                f"bit-identity")
    for kind in ARMS:
        results[kind].pop("probe_streams")
        results[kind].pop("serve_streams")

    speedup = (results["shared"]["prefill_tokens_per_s"]
               / results["dense"]["prefill_tokens_per_s"])
    mig_ratio = (results["shared"]["migration_bytes"]
                 / results["dense"]["migration_bytes"])
    results["prefill_speedup_shared_vs_dense"] = speedup
    results["migration_bytes_ratio_shared_vs_dense"] = mig_ratio
    results["scenario"] = {
        "arch": ARCH, "requests": n_requests, "batch": BATCH,
        "max_seq": MAX_SEQ, "block_size": BLOCK_SIZE,
        "prefix_len": PREFIX_LEN, "prefill_chunk": PREFILL_CHUNK,
        "decode_chunk": DECODE_CHUNK,
    }
    results["meta"] = bench_meta(seed=SEED, config=results["scenario"])
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)

    for kind in ARMS:
        r = results[kind]
        emit(f"prefix_{kind}", r["wall_s"] * 1e6,
             f"{r['prefill_tokens_per_s']:.1f}pretok/s"
             f"|{r['tokens_per_s']:.1f}tok/s|{r['j_per_token']:.2f}J/tok"
             f"|{r['hbm_bytes_per_slot']/1024:.1f}KiB/slot"
             f"|mig={r['migration_bytes']}B"
             f"|skip={r['prefill_tokens_skipped']}|cow={r['cow_copies']}")
    emit("prefix_prefill_speedup", 0.0, f"{speedup:.2f}x")
    emit("prefix_migration_ratio", 0.0, f"{mig_ratio:.3f}x")

    # acceptance gates: sharing must actually fire, shrink migrations,
    # and not cost pool residency vs unshared paging
    assert results["shared"]["prefill_tokens_skipped"] > 0, (
        "prefix sharing never skipped a row — registry path broken")
    assert results["shared"]["cow_copies"] > 0, (
        "no copy-on-write pivot fired — the partial tail block should "
        "CoW on every sharing admission")
    assert mig_ratio < 1.0, (
        f"prefix sharing did not shrink migration bytes ({mig_ratio:.3f}x)")
    assert (results["shared"]["hbm_bytes_per_slot"]
            <= results["paged"]["hbm_bytes_per_slot"] + 1e-9), (
        "sharing increased peak pool residency over unshared paging")
    if min_prefill_speedup is not None and speedup < min_prefill_speedup:
        raise SystemExit(
            f"prefix-sharing regression: shared/dense effective prefill "
            f"throughput {speedup:.2f}x below threshold "
            f"{min_prefill_speedup}x")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--min-prefill-speedup", type=float, default=None,
                    help="fail loudly when shared/dense effective prefill "
                         "tokens-per-s falls below this ratio (CI smoke)")
    ap.add_argument("--json-path", default="BENCH_prefix.json")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto/Chrome trace_event JSON of the "
                         "shared arm's serve run to this path")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.requests, args.min_prefill_speedup, args.json_path,
        args.trace_out)


if __name__ == "__main__":
    main()
