"""Benchmark harness: one module per paper table/figure (+ roofline and the
beyond-paper steering policy).  Prints ``name,us_per_call,derived`` CSV."""

from __future__ import annotations

import sys
import traceback

from benchmarks import (cell_caps, chaos, fig1_power_trace, fig2_sed_sweep,
                        fig3_ed_sweep, fleet_power, migration, pareto_fleet,
                        prefix_sharing, roofline, serving_throughput,
                        steering_policy, table1_task_profile,
                        table2_optimal_caps, traffic_slo)

BENCHES = [
    ("table1", table1_task_profile),
    ("fig2", fig2_sed_sweep),
    ("fig3", fig3_ed_sweep),
    ("table2", table2_optimal_caps),
    ("fig1", fig1_power_trace),
    ("steering", steering_policy),
    ("roofline", roofline),
    ("cell_caps", cell_caps),
    ("serve", serving_throughput),
    ("fleet", fleet_power),
    ("migrate", migration),
    ("traffic", traffic_slo),
    ("chaos", chaos),
    ("prefix", prefix_sharing),
    ("pareto", pareto_fleet),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in BENCHES:
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
