"""Chaos benchmark: fault-injected fleet, no-recovery vs watchdog vs
watchdog + shadow checkpoints.

One deterministic open-loop serving scenario (``repro.workload`` diurnal
trace, four serve jobs, five nodes — one spare) runs four times under
the SAME fault schedule (``repro.fleet.faults.chaos_schedule``: node
crashes, a sleep/wake hang, stuck and flaky cap-apply windows, telemetry
dropout/corruption, a straggler):

  nofault    the calm baseline — no injector at all.  Its useful-token
             count is the ceiling recovery is measured against.
  none       faults injected, NO recovery: a crashed node holds its job
             (and every in-flight stream) forever, nobody fences it.
  watchdog   the fleet watchdog fences nodes whose heartbeat misses the
             deadline and re-queues their jobs through the supervisor's
             restart budget — but without shadow checkpoints the crash
             destroys all in-flight decode.
  ckpt       watchdog + periodic shadow slot checkpoints: a crash loses
             at most one checkpoint interval of decode; everything else
             replays from the shadow on the adopting node.

Reported per arm: useful tokens delivered (net of crash-destroyed
work), SLO attainment, total energy and J/useful-token, plus the fault
counters (crashes, dead_declared, checkpoints, replayed/lost tokens,
cap retries, degraded quanta).  The headline number is useful-token
recovery::

    recovery = (useful_ckpt - useful_none) / (useful_nofault - useful_none)

i.e. what fraction of the work the faults would have destroyed the full
recovery stack claws back.  Machine-readable results go to
``BENCH_chaos.json``.

Smoke gates (CI): recovery must reach ``--min-recovery`` (default
0.9), the ckpt arm's attainment must be strictly above the no-recovery
arm's, every fault class must actually fire, and two same-seed ckpt
runs must be bit-identical (fleet + SLO counters).

  PYTHONPATH=src:. python benchmarks/chaos.py \
      [--nodes 5] [--duration 120] [--seed 0] [--min-recovery 0.9]
"""

from __future__ import annotations

import argparse
import dataclasses
import json

from benchmarks.common import bench_meta, emit
from repro.configs.registry import get_model_config
from repro.fleet import FaultInjector, ServeJob, SimulatedCluster, \
    chaos_schedule
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.obs import (EnergyLedger, Tracer, dump_chrome_trace,
                       dump_metrics_jsonl)
from repro.workload import SLOTracker, WorkloadDriver, diurnal_trace

#: Serve-token value in the fleet objective.
SERVE_VALUE = 2.0

#: Watchdog deadline: a node missing quanta this long is declared dead.
#: Two quanta of slack over the 1 s control quantum — short enough to
#: fence a crash fast, long enough that a transfer-occupied node's
#: skipped quantum never false-positives.
WATCHDOG_S = 2.5

#: Shadow-checkpoint cadence: a crash loses at most this much decode.
CKPT_S = 4.0

#: Virtual seconds a crashed node takes to come back once fenced.
REPAIR_S = 10.0


def _make_trace(seed: int, duration: float, base_rps: float):
    return diurnal_trace(seed=seed, until_s=duration, base_rps=base_rps,
                         amplitude=0.9, period_s=duration / 2.0)


def _attainment(counters: dict) -> float:
    """Overall SLO attainment with OFFERED requests as the denominator,
    so streams a dead node swallowed count against the arm."""
    offered = sum(c["offered"] for c in counters["slo"].values())
    met = sum(c["met"] for c in counters["slo"].values())
    return met / offered if offered else 1.0


def _run_arm(trace, schedule, n_nodes: int, n_jobs: int, duration: float,
             seed: int, *, watchdog: bool, ckpt: bool,
             tracer=None) -> dict:
    cfg = get_model_config("llama3.2-3b")
    injector = (FaultInjector(list(schedule), repair_s=REPAIR_S, seed=seed)
                if schedule is not None else None)
    cluster = SimulatedCluster(
        n_nodes=n_nodes, cabinet_size=4, policy="sensitivity",
        faults=injector,
        watchdog_deadline_s=WATCHDOG_S if watchdog else None,
        shadow_ckpt_s=CKPT_S if ckpt else None, tracer=tracer)
    tracker = SLOTracker(sink=cluster.telemetry)
    driver = WorkloadDriver(list(trace), tracker)
    jobs = [ServeJob(f"svc-{i}", cfg, batch=8, prompt=256, new_tokens=64,
                     total_requests=0, decode_chunk=8, open_loop=True,
                     partial=True, migrate=True, value=SERVE_VALUE,
                     slo=tracker, max_restarts=64, backoff_jitter=0.25)
            for i in range(n_jobs)]
    budget = 0.75 * n_nodes * DEFAULT_SUPERCHIP.p_max
    counters = cluster.run(jobs=jobs, budget=budget, until_s=duration,
                           workload=driver)
    if tracer is not None:
        # exported chaos traces must balance the books: every attributed
        # joule either landed in telemetry or is a recorded sample loss
        EnergyLedger(tracer).assert_conserved(counters["energy_j"])
    useful = sum(j.emitted for j in jobs)
    energy = counters["energy_j"] + counters["idle_energy_j"]
    return {
        "useful_tokens": useful,
        "attainment": _attainment(counters),
        "energy_j": energy,
        "j_per_useful_token": energy / useful if useful else 0.0,
        "fleet": counters,
    }


def run(n_nodes: int = 5, duration: float = 120.0, seed: int = 0,
        base_rps: float = 12.0, min_recovery: float | None = None,
        json_path: str = "BENCH_chaos.json",
        trace_out: str | None = None,
        metrics_out: str | None = None) -> dict:
    n_jobs = n_nodes - 1                       # one spare for adoption
    trace = _make_trace(seed, duration, base_rps)
    # faults target only the job-bearing nodes (the spare exists to
    # absorb a fenced job without waiting out a repair)
    cabinet = 4
    names = [f"cab{i // cabinet}/n{i:02d}" for i in range(n_jobs)]
    schedule = chaos_schedule(seed, names, duration, crashes=2, hangs=1,
                              cap_faults=2, telemetry_faults=2,
                              stragglers=1, repair_s=REPAIR_S)

    arms = {
        "nofault": _run_arm(trace, None, n_nodes, n_jobs, duration, seed,
                            watchdog=False, ckpt=False),
        "none": _run_arm(trace, schedule, n_nodes, n_jobs, duration, seed,
                         watchdog=False, ckpt=False),
        "watchdog": _run_arm(trace, schedule, n_nodes, n_jobs, duration,
                             seed, watchdog=True, ckpt=False),
        "ckpt": _run_arm(trace, schedule, n_nodes, n_jobs, duration, seed,
                         watchdog=True, ckpt=True),
    }
    # the determinism contract: an identical-seed replay of the full
    # recovery stack — fault delivery, watchdog verdicts, checkpoint
    # replay, SLO accounting — must be bit-identical.  The replay arm
    # carries the exported trace when one was asked for (tracing is
    # observation-only, so the arms still compare equal).
    tracer = Tracer() if (trace_out or metrics_out) else None
    ckpt2 = _run_arm(trace, schedule, n_nodes, n_jobs, duration, seed,
                     watchdog=True, ckpt=True, tracer=tracer)
    if trace_out:
        dump_chrome_trace(tracer, trace_out, process_name="chaos-fleet")
        emit("chaos_trace_spans", 0.0, str(len(tracer.spans)))
    if metrics_out:
        dump_metrics_jsonl(tracer, metrics_out)

    lost_to_faults = (arms["nofault"]["useful_tokens"]
                      - arms["none"]["useful_tokens"])
    recovery = {
        name: ((arms[name]["useful_tokens"] - arms["none"]["useful_tokens"])
               / lost_to_faults if lost_to_faults > 0 else float("inf"))
        for name in ("watchdog", "ckpt")}

    results = {
        "arms": arms,
        "recovery": recovery,
        "scenario": {
            "nodes": n_nodes, "jobs": n_jobs, "duration_s": duration,
            "seed": seed, "base_rps": base_rps, "arrivals": len(trace),
            "watchdog_deadline_s": WATCHDOG_S, "shadow_ckpt_s": CKPT_S,
            "repair_s": REPAIR_S, "serve_value": SERVE_VALUE,
            "fault_schedule": [dataclasses.asdict(e) for e in schedule],
        },
    }
    results["meta"] = bench_meta(seed=seed, config=results["scenario"])
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)

    for name, r in arms.items():
        fc = r["fleet"]
        emit(f"chaos_{name}", fc["busy_s"] * 1e6,
             f"{r['useful_tokens']}tok|att={r['attainment']:.3f}"
             f"|{r['j_per_useful_token']*1e3:.2f}mJ/tok"
             f"|crash={fc['crashes']}|dead={fc['dead_declared']}"
             f"|ckpt={fc['checkpoints']}|replay={fc['replayed_tokens']}"
             f"|lost={fc['lost_tokens']}")
    emit("chaos_recovery_watchdog", 0.0, f"{recovery['watchdog']:.3f}")
    emit("chaos_recovery_ckpt", 0.0, f"{recovery['ckpt']:.3f}")

    # -- acceptance gates ---------------------------------------------------
    # the scenario must actually exercise every fault class...
    for name in ("none", "watchdog", "ckpt"):
        assert arms[name]["fleet"]["crashes"] >= 1, \
            f"{name} arm: no crash fired — schedule broken"
    assert arms["ckpt"]["fleet"]["cap_retries"] >= 1, \
        "cap-fault windows never exercised the retry backend"
    assert arms["ckpt"]["fleet"]["degraded_quanta"] >= 1, \
        "telemetry faults never pushed the controller into degraded mode"
    for name in ("watchdog", "ckpt"):
        assert arms[name]["fleet"]["dead_declared"] >= 1, \
            f"{name} arm: watchdog never fenced a node"
    assert arms["ckpt"]["fleet"]["checkpoints"] >= 1, \
        "ckpt arm never took a shadow checkpoint"
    assert arms["ckpt"]["fleet"]["replayed_tokens"] >= 1, \
        "ckpt arm never replayed in-flight tokens from a shadow"
    # ...the faults must hurt (else recovery is meaningless)...
    assert lost_to_faults > 0, \
        "no-recovery arm lost nothing to the faults — scenario broken"
    # ...replay must be bit-identical...
    assert arms["ckpt"] == ckpt2, \
        "same-seed ckpt runs diverged — determinism broken"
    # ...and the recovery stack must actually recover
    assert arms["ckpt"]["attainment"] > arms["none"]["attainment"], (
        f"ckpt attainment {arms['ckpt']['attainment']:.4f} not above "
        f"no-recovery {arms['none']['attainment']:.4f}")
    # (small tolerance: checkpointing pays transfer time the
    # watchdog-only arm does not, which can cost a hair of throughput
    # even while it halves the lost-token count)
    assert recovery["ckpt"] >= recovery["watchdog"] - 0.05, (
        "checkpoints recovered materially LESS than watchdog alone "
        f"({recovery['ckpt']:.3f} < {recovery['watchdog']:.3f})")
    assert arms["ckpt"]["fleet"]["lost_tokens"] <= \
        arms["watchdog"]["fleet"]["lost_tokens"], (
        "shadow checkpoints did not reduce crash-lost tokens")
    if min_recovery is not None and recovery["ckpt"] < min_recovery:
        raise SystemExit(
            f"chaos regression: ckpt useful-token recovery "
            f"{recovery['ckpt']:.3f} below threshold {min_recovery}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=5)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--base-rps", type=float, default=12.0)
    ap.add_argument("--min-recovery", type=float, default=None,
                    help="fail loudly when the watchdog+checkpoint arm "
                         "recovers less than this fraction of the "
                         "useful tokens the no-recovery arm lost (CI "
                         "smoke)")
    ap.add_argument("--json-path", default="BENCH_chaos.json")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto/Chrome trace_event JSON of the "
                         "ckpt replay arm to this path")
    ap.add_argument("--metrics-out", default=None,
                    help="write the per-quantum counter stream of the "
                         "ckpt replay arm to this path as JSONL")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.nodes, args.duration, args.seed, args.base_rps,
        args.min_recovery, args.json_path,
        trace_out=args.trace_out, metrics_out=args.metrics_out)


if __name__ == "__main__":
    main()
