"""Paper Fig. 3: Euclidean distance of normalized energy/runtime per task.

Reproduces: max distance at the lowest cap (slowest AND energy-hungry
corner, distances can exceed 1); minima in the low-mid band; ED argmin is
Pareto-optimal (Global Criterion property)."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import (ed_argmin_is_pareto, ed_optimal_cap,
                        euclidean_distance, measure_sweep)
from repro.models.lsms import paper_calibrated_tasks


def run() -> dict:
    table = measure_sweep(paper_calibrated_tasks())

    def compute():
        return {t: euclidean_distance(table, t) for t in table.tasks()}

    curves, us = timed(compute)
    caps = {t: ed_optimal_cap(table, t) for t in table.tasks()}
    sweep = sorted(table.caps())
    for t, cap in caps.items():
        emit(f"fig3_ed_cap_{t}", us, cap)
    # lowest cap is the WORST (max distance) for busy tasks (paper Fig 3)
    worst = max(curves["zgemm_ts64"], key=curves["zgemm_ts64"].get)
    assert worst == sweep[0], (worst, sweep[0])
    emit("fig3_zgemm64_worst_cap", us, worst)
    # Pareto property of the Global Criterion argmin
    pareto = all(ed_argmin_is_pareto(table, t) for t in table.tasks())
    assert pareto
    emit("fig3_all_argmin_pareto", us, pareto)
    return {"curves": curves, "caps": caps}


if __name__ == "__main__":
    run()
