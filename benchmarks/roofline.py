"""Roofline analysis over the dry-run artifacts (assignment §Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs   / (chips * 197e12)
  memory term     = HLO_bytes   / (chips * 819e9)
  collective term = coll_bytes  / (chips * 50e9)
(all artifact numbers are per-device from the SPMD program, so the formulas
reduce to per-device / per-chip-peak)
plus the dominant term, MODEL_FLOPS/HLO_FLOPs useful ratio, and a roofline
fraction = model-flops-time / dominant-term-time (how close the step is to
the best achievable on the dominant resource)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.hw.tpu import DEFAULT_CHIP

ARTIFACT_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def analyze_record(rec: dict, chip=DEFAULT_CHIP) -> dict:
    compute_s = rec["flops_per_device"] / chip.peak_flops_bf16
    memory_s = rec["bytes_per_device"] / chip.hbm_bandwidth
    coll_s = rec["coll_bytes_per_device"] / chip.ici_bandwidth
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    hlo_flops_global = rec["flops_per_device"] * rec["chips"]
    model_flops = rec.get("model_flops_global", 0.0)
    useful = model_flops / hlo_flops_global if hlo_flops_global > 0 else 0.0

    # ideal step time: the analytic minimum work on EITHER resource
    # (model flops at peak MXU, or model bytes at peak HBM) — whichever is
    # larger is the true roofline bound for this cell.
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_model_config
    from repro.hw.flops import model_bytes
    mbytes = rec.get("model_bytes_global")
    if mbytes is None:
        mbytes = model_bytes(get_model_config(rec["arch"]),
                             SHAPES[rec["shape"]])
    ideal_s = max(model_flops / (rec["chips"] * chip.peak_flops_bf16),
                  mbytes / (rec["chips"] * chip.hbm_bandwidth))
    frac = ideal_s / terms[dominant] if terms[dominant] > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "rules": rec.get("rules", "?"),
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "useful_ratio": useful, "roofline_fraction": min(frac, 1.0),
        "ideal_s": ideal_s,
        "step_s_bound": max(terms.values()),
    }


def load_all(directory: str = ARTIFACT_DIR, pattern: str = "*.json"
             ) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(directory, pattern))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def table(records=None, mesh: str | None = "16x16") -> list[dict]:
    records = records if records is not None else load_all()
    rows = [analyze_record(r) for r in records
            if mesh is None or r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':25s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'bound':>10s} "
           f"{'useful':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:25s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:10.4f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {r['roofline_fraction']:9.3f}")
    return "\n".join(lines)


def run() -> dict:
    rows = table(mesh=None)
    if not rows:
        emit("roofline_cells", 0.0, 0)
        return {"rows": []}
    emit("roofline_cells", 0.0, len(rows))
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    emit("roofline_worst_cell", 0.0,
         f"{worst['arch']}/{worst['shape']}/{worst['mesh']}"
         f"={worst['roofline_fraction']:.3f}")
    coll_bound = [r for r in rows if r["dominant"] == "collective"]
    emit("roofline_collective_bound_cells", 0.0, len(coll_bound))
    mean_frac = sum(r["roofline_fraction"] for r in rows) / len(rows)
    emit("roofline_mean_fraction", 0.0, round(mean_frac, 3))
    return {"rows": rows}


if __name__ == "__main__":
    print(format_table(table(mesh=None)))
    run()
