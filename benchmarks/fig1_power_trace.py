"""Paper Fig. 1: 5 ms power trace of the application at the default cap.

Reproduces: chip dominates superchip power; two SCF iterations visible as
power drops when computation moves to the host (idle phases); cumulative
energy split chip vs host."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import generate_trace
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.models.lsms import scf_phase_sequence


def run() -> dict:
    phases = scf_phase_sequence()

    def compute():
        return generate_trace(phases, cap=DEFAULT_SUPERCHIP.p_default,
                              sample_ms=5.0)

    trace, us = timed(compute, repeats=1)
    emit("fig1_samples", us, len(trace.points))
    emit("fig1_energy_total_j", us, round(trace.energy_total, 1))
    emit("fig1_energy_chip_j", us, round(trace.energy_chip, 1))
    emit("fig1_energy_host_j", us, round(trace.energy_host, 1))
    # paper: the accelerator dominates both power and energy
    assert trace.energy_chip > 5 * trace.energy_host
    # idle dips: min superchip power clearly below the busy plateau
    arr = trace.as_arrays()
    emit("fig1_p_busy_max_w", us, round(float(arr["superchip"].max()), 1))
    emit("fig1_p_idle_min_w", us, round(float(arr["superchip"].min()), 1))
    assert float(arr["superchip"].min()) < 0.6 * float(arr["superchip"].max())
    return {"trace": trace}


if __name__ == "__main__":
    run()
