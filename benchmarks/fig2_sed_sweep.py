"""Paper Fig. 2: speedup-energy-delay per GPU task across the cap sweep.

Reproduces: compute-bound zgemm64 peaks near the top of the sweep (paper:
900 W of 1000 W); memory-bound buildKKRMatrix peaks low (paper: 300 W);
gpu-compute-idle peaks at/near the floor (paper: 200 W, SED 1.71)."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import measure_sweep, sed_optimal_cap, speedup_energy_delay
from repro.models.lsms import paper_calibrated_tasks


def run() -> dict:
    table = measure_sweep(paper_calibrated_tasks())

    def compute():
        return {t: speedup_energy_delay(table, t) for t in table.tasks()}

    curves, us = timed(compute)
    caps = {t: sed_optimal_cap(table, t) for t in table.tasks()}
    for t, cap in caps.items():
        emit(f"fig2_sed_cap_{t}", us, cap)
    sweep = sorted(table.caps())
    assert caps["zgemm_ts64"] >= sweep[-4], caps       # high-cap peak
    assert caps["buildKKRMatrix"] <= sweep[3], caps    # low-cap peak
    assert caps["gpu_compute_idle"] <= sweep[2], caps  # floor-seeking
    idle_sed = max(curves["gpu_compute_idle"].values())
    emit("fig2_idle_peak_sed", us, round(idle_sed, 3))
    return {"curves": curves, "caps": caps}


if __name__ == "__main__":
    run()
