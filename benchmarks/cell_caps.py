"""Beyond-paper: the paper's cap-selection methodology applied to EVERY
dry-run cell of the framework.

Each (arch × shape × mesh) cell's roofline terms become a Task (its
per-chip compute/memory/collective profile); the cap sweep + SED/ED then
recommend a per-cell superchip cap — i.e. "at which power limit should the
fleet run THIS workload".  Writes artifacts/cell_caps.csv.

Expected structure (and asserted below): compute-bound training cells get
high caps; memory-bound decode cells get deep caps with large energy
savings at ~zero runtime cost — the paper's Table-2 asymmetry, now over 62
real workload cells instead of 8 LSMS kernels.
"""

from __future__ import annotations

import csv
import os

from benchmarks.common import emit, timed
from benchmarks.roofline import load_all
from repro.core import (Task, ed_optimal_cap, measure_sweep, sed_optimal_cap,
                        table2)
from repro.hw.tpu import DEFAULT_CHIP


def cell_tasks(rec: dict) -> tuple[Task, Task]:
    """Two power-model Tasks per dry-run cell:

      hlo:   per-chip roofline terms as compiled (CPU-proxy; memory-heavy,
             see EXPERIMENTS.md §Dry-run caveat)
      ideal: the analytic MODEL_FLOPS/model_bytes terms (TPU-expected
             arithmetic intensity)
    The ideal variant carries the honest compute/memory contrast between
    training and decode; the hlo variant shows what the proxy would decide.
    """
    name = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
    hlo = Task(name + "|hlo",
               flops=max(rec["flops_per_device"], 0.0),
               hbm_bytes=max(rec["bytes_per_device"], 0.0),
               coll_bytes=max(rec["coll_bytes_per_device"], 0.0))
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_model_config
    from repro.hw.flops import model_bytes
    chips = rec["chips"]
    ideal = Task(name + "|ideal",
                 flops=rec.get("model_flops_global", 0.0) / chips,
                 hbm_bytes=model_bytes(get_model_config(rec["arch"]),
                                       SHAPES[rec["shape"]]) / chips)
    return hlo, ideal


def run() -> dict:
    records = load_all()
    if not records:
        emit("cell_caps_cells", 0.0, 0)
        return {"rows": []}

    tasks = [t for r in records for t in cell_tasks(r)]

    def compute():
        return measure_sweep(tasks)

    table, us = timed(compute, repeats=1)
    rows = table2(table)

    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/cell_caps.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["cell", "sed_cap_w", "ed_cap_w", "sed_dE_pct",
                    "ed_dE_pct", "sed_dt_pct", "ed_dt_pct"])
        for r in rows:
            w.writerow([r.task, r.sed_cap, r.ed_cap,
                        round(r.sed_energy_reduction_pct, 2),
                        round(r.ed_energy_reduction_pct, 2),
                        round(r.sed_runtime_increase_pct, 2),
                        round(r.ed_runtime_increase_pct, 2)])

    ideal = [r for r in rows if r.task.endswith("|ideal")]
    dec_i = [r for r in ideal if "decode" in r.task or "long" in r.task]
    trn_i = [r for r in ideal if "train" in r.task]
    emit("cell_caps_cells", us, len(records))
    mean_dec_cap = sum(r.sed_cap for r in dec_i) / max(len(dec_i), 1)
    mean_trn_cap = sum(r.sed_cap for r in trn_i) / max(len(trn_i), 1)
    emit("cell_caps_ideal_decode_mean_sed_cap_w", us, round(mean_dec_cap, 1))
    emit("cell_caps_ideal_train_mean_sed_cap_w", us, round(mean_trn_cap, 1))
    # the paper's Table-2 asymmetry at fleet scale: compute-bound training
    # runs near-uncapped; memory-bound decode gets deep caps...
    assert mean_trn_cap > mean_dec_cap
    # ...and decode's SED caps are essentially runtime-free
    mean_dec_save = (sum(r.sed_energy_reduction_pct for r in dec_i)
                     / max(len(dec_i), 1))
    max_dec_dt = max((r.sed_runtime_increase_pct for r in dec_i),
                     default=0.0)
    emit("cell_caps_ideal_decode_mean_sed_saving_pct", us,
         round(mean_dec_save, 2))
    emit("cell_caps_ideal_decode_max_sed_dt_pct", us, round(max_dec_dt, 2))
    assert mean_dec_save > 5.0
    return {"rows": rows}


if __name__ == "__main__":
    run()
