"""Serving throughput under heavy mixed-prompt-length traffic.

Runs the SAME request stream through the continuous-batching runtime
(``repro.serving.engine.ServeEngine``) and the pre-rewrite static
bucketed engine (``repro.serving.legacy.StaticServeEngine``) and reports,
per engine:

  * tokens/s       generated-token throughput (wall clock)
  * J/token        modeled decode+prefill energy per generated token
                   (PowerManager's analytic backend under per-phase caps)
  * p50/p99 (s)    per-request completion latency (all requests arrive
                   at t=0; completion is observed at chunk granularity)

and the headline ``serve_speedup`` row.  Machine-readable results go to
``BENCH_serve.json`` so the perf trajectory is tracked PR over PR; pass
``--min-speedup`` (the CI smoke threshold) to fail loudly on regression.

  PYTHONPATH=src:. python benchmarks/serving_throughput.py \
      [--requests 24] [--min-speedup 1.5] [--json-path BENCH_serve.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from benchmarks.common import bench_meta, emit
from repro.configs.base import reduced
from repro.configs.registry import get_model_config, get_run_config
from repro.models import lm
from repro.models.layers import Ctx
from repro.models.params import init_params
from repro.power import PowerManager
from repro.serving.engine import Request, ServeEngine, serve_phase_tasks
from repro.serving.legacy import StaticServeEngine
from repro.sharding import RULE_SETS

ARCH = "llama3.2-3b"
MAX_SEQ = 64
BATCH = 4
DECODE_CHUNK = 8


def _scenario(n_requests: int) -> list[tuple[list[int], int]]:
    """Heavy mixed traffic: prompt lengths sweep 3..26 with (for the
    default 24 requests) every length distinct — the realistic shape of
    live traffic, and the case equal-length bucketing degrades to
    batch-of-1.  New-token budgets sweep 8..23."""
    out = []
    for i in range(n_requests):
        plen = 3 + (7 * i) % 24
        new = 8 + (5 * i) % 16
        prompt = [(3 * i + j) % 512 for j in range(plen)]
        out.append((prompt, new))
    return out


def _requests(scenario) -> list[Request]:
    return [Request(uid=i, prompt=list(p), max_new_tokens=n)
            for i, (p, n) in enumerate(scenario)]


def _percentile(xs: list[float], q: float) -> float:
    xs = sorted(xs)
    i = min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))
    return xs[i]


def _run_one(engine, scenario) -> dict:
    reqs = _requests(scenario)
    t0 = time.perf_counter()
    done = engine.generate(reqs)
    wall = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    assert n_tok == sum(n for _, n in scenario), "engine dropped tokens"
    lat = [engine.completion_s[r.uid] for r in done]
    pm = engine.power
    # aggregate counter, not pm.history — history trims to its tail, which
    # would silently undercount long runs
    energy = pm.modeled_energy_j if pm is not None else 0.0
    return {
        "wall_s": wall,
        "tokens": n_tok,
        "tokens_per_s": n_tok / wall,
        "j_per_token": energy / n_tok if n_tok else 0.0,
        "p50_s": _percentile(lat, 0.50),
        "p99_s": _percentile(lat, 0.99),
    }


def _build(kind: str, scenario):
    cfg = reduced(get_model_config(ARCH))
    run = get_run_config(ARCH, remat="none", logits_chunk=64)
    ctx = Ctx(run, RULE_SETS[run.serve_rules_name], None)
    params = init_params(lm.model_decls(cfg), jax.random.PRNGKey(0))
    new_tokens = max(n for _, n in scenario)
    pm = PowerManager(tasks=serve_phase_tasks(
        get_model_config(ARCH), batch=128, prompt=32768,
        new_tokens=new_tokens, chips=256))
    if kind == "continuous":
        eng = ServeEngine(cfg, run, ctx, params, batch_size=BATCH,
                          max_seq=MAX_SEQ, power=pm,
                          decode_chunk=DECODE_CHUNK)
    else:
        eng = StaticServeEngine(cfg, run, ctx, params, batch_size=BATCH,
                                max_seq=MAX_SEQ, power=pm)
    return eng


def run(n_requests: int = 24, min_speedup: float | None = None,
        json_path: str = "BENCH_serve.json") -> dict:
    scenario = _scenario(n_requests)
    results = {}
    for kind in ("continuous", "legacy"):
        # warmup on a tiny slice so jit tracing is off the clock for both
        warm = _build(kind, scenario)
        warm.generate(_requests(scenario[:2]))
        eng = _build(kind, scenario)
        results[kind] = _run_one(eng, scenario)
    speedup = (results["continuous"]["tokens_per_s"]
               / results["legacy"]["tokens_per_s"])
    results["speedup"] = speedup
    results["scenario"] = {"arch": ARCH, "requests": n_requests,
                           "batch": BATCH, "max_seq": MAX_SEQ,
                           "decode_chunk": DECODE_CHUNK}
    results["meta"] = bench_meta(config=results["scenario"])
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)
    for kind in ("continuous", "legacy"):
        r = results[kind]
        emit(f"serve_{kind}", r["wall_s"] * 1e6,
             f"{r['tokens_per_s']:.1f}tok/s|{r['j_per_token']:.2f}J/tok"
             f"|p50={r['p50_s']:.2f}s|p99={r['p99_s']:.2f}s")
    emit("serve_speedup", 0.0, f"{speedup:.2f}x")
    if min_speedup is not None and speedup < min_speedup:
        raise SystemExit(
            f"serving throughput regression: continuous batching is only "
            f"{speedup:.2f}x the static engine (threshold {min_speedup}x)")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail loudly when continuous/legacy tokens-per-s "
                         "falls below this ratio (CI smoke threshold)")
    ap.add_argument("--json-path", default="BENCH_serve.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.requests, args.min_speedup, args.json_path)


if __name__ == "__main__":
    main()
