"""Shared benchmark utilities: timing + CSV emission + result metadata.

Every benchmark prints ``name,us_per_call,derived`` rows: us_per_call is the
wall time of the (repeated) computation; derived is the headline number the
paper artifact reports.  Benchmarks that additionally write a
``BENCH_*.json`` artifact stamp it with ``bench_meta()`` so any archived
result is traceable to the schema, seed, scenario config and commit that
produced it.
"""

from __future__ import annotations

import os
import subprocess
import time

#: Version of the shared ``meta`` block every ``BENCH_*.json`` carries.
#: Bump when the meta layout (not the benchmark payloads) changes.
BENCH_SCHEMA_VERSION = 1

#: The repo's tier-1 gate — recorded so an archived artifact names the
#: test bar its commit was held to.
TIER1_CMD = "PYTHONPATH=src python -m pytest -x -q"


def git_commit() -> str | None:
    """Commit hash of the repo this benchmark ran from (None outside a
    checkout — e.g. an unpacked artifact tarball)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    return out.stdout.strip() if out.returncode == 0 else None


def bench_meta(seed=None, config: dict | None = None) -> dict:
    """The shared ``meta`` block stamped into every ``BENCH_*.json``:
    schema version, the run's seed, the scenario config knobs, and the
    commit + tier-1 command the artifact is traceable to."""
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "seed": seed,
        "config": dict(config or {}),
        "commit": git_commit(),
        "tier1": TIER1_CMD,
    }


def timed(fn, repeats: int = 5):
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
