"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows: us_per_call is the
wall time of the (repeated) computation; derived is the headline number the
paper artifact reports.
"""

from __future__ import annotations

import time


def timed(fn, repeats: int = 5):
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
