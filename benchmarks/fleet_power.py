"""Fleet benchmark: mixed train+serve traffic under a shrinking facility cap.

Runs the SAME heterogeneous job queue — compute-bound training,
decode-heavy serving (memory-bound), prefill-heavy serving, and a
small-model training job — through ``repro.fleet.SimulatedCluster``
twice at the SAME facility budget trace:

  even          static even split of the budget over busy nodes (the
                naive baseline: headroom strands on nodes that can't
                convert watts into tokens)
  sensitivity   hierarchical FleetPowerController steering — water-fill
                over node requests plus marginal-perf-per-watt transfers

and reports, per policy: fleet tokens/s, modeled J/token, grants,
preemptions and cap violations.  The budget trace shrinks in steps from
85% to 40% of the fleet's p_max and includes one deep dip that forces a
train-job preemption + resume (identical in both runs).

Machine-readable results go to ``BENCH_fleet.json``.  The smoke gates
(CI): ``--min-speedup`` fails the run when sensitivity steering stops
beating the even split on fleet tokens/s, and J/token must be no worse
(within ``J_TOK_TOL``).  Budget conservation is asserted inside every
``FleetPowerController.redistribute`` call (and property-tested in
``tests/test_fleet.py``); here we re-assert it over the recorded
allocations of both runs.

  PYTHONPATH=src:. python benchmarks/fleet_power.py \
      [--nodes 6] [--duration 60] [--min-speedup 1.05]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import bench_meta, emit
from repro.configs.registry import get_model_config
from repro.fleet import ServeJob, SimulatedCluster, TrainJob
from repro.hw.tpu import DEFAULT_SUPERCHIP

#: Sensitivity steering must not pay for its throughput in efficiency:
#: J/token no worse than the even split, with float headroom only.
J_TOK_TOL = 1.001


def _jobs(n_nodes: int) -> list:
    """One job per node, round-robin over four heterogeneous shapes."""
    llama = get_model_config("llama3.2-3b")
    mamba = get_model_config("mamba2-370m")
    shapes = [
        lambda i: TrainJob(f"train-llama-{i}", llama, batch=8, seq=512,
                           total_steps=10**9),
        lambda i: ServeJob(f"serve-decode-{i}", llama, batch=64,
                           prompt=2048, new_tokens=512,
                           total_requests=10**9, decode_chunk=32),
        lambda i: ServeJob(f"serve-prefill-{i}", llama, batch=16,
                           prompt=8192, new_tokens=32,
                           total_requests=10**9, decode_chunk=32),
        lambda i: TrainJob(f"train-mamba-{i}", mamba, batch=8, seq=512,
                           total_steps=10**9),
    ]
    return [shapes[i % len(shapes)](i) for i in range(n_nodes)]


def _budget_trace(n_nodes: int, duration: float) -> list:
    """Shrinking facility cap, with a deep dip near the end that forces a
    preemption and a recovery leg that resumes the preempted job."""
    p = n_nodes * DEFAULT_SUPERCHIP.p_max
    legs = [(0.00, 0.80), (0.15, 0.60), (0.35, 0.50), (0.55, 0.42),
            (0.80, 0.12), (0.88, 0.42)]
    return [(f * duration, frac * p) for f, frac in legs]


def _conservation(cluster) -> None:
    """Sum(node grants) <= facility budget at every recorded step."""
    for alloc in cluster.allocations:
        total = sum(alloc.node_w.values())
        floors = len(alloc.node_w) * DEFAULT_SUPERCHIP.p_floor
        if alloc.facility_w >= floors:
            assert total <= alloc.facility_w + 1e-6, \
                (alloc.t, total, alloc.facility_w)


def run(n_nodes: int = 6, duration: float = 60.0,
        min_speedup: float | None = None,
        json_path: str = "BENCH_fleet.json") -> dict:
    trace = _budget_trace(n_nodes, duration)
    results: dict = {}
    clusters = {}
    for policy in ("even", "sensitivity"):
        cluster = SimulatedCluster(n_nodes=n_nodes,
                                   cabinet_size=max(n_nodes // 2, 1),
                                   policy=policy)
        counters = cluster.run(jobs=_jobs(n_nodes), budget=trace,
                               until_s=duration)
        _conservation(cluster)
        results[policy] = counters
        clusters[policy] = cluster

    speedup = (results["sensitivity"]["tokens_per_s"]
               / results["even"]["tokens_per_s"])
    j_ratio = (results["sensitivity"]["j_per_token"]
               / results["even"]["j_per_token"])
    results["speedup"] = speedup
    results["j_per_token_ratio"] = j_ratio
    results["scenario"] = {
        "nodes": n_nodes, "duration_s": duration,
        "budget_trace_w": [[t, w] for t, w in trace],
        "job_shapes": ["train-llama", "serve-decode", "serve-prefill",
                       "train-mamba"],
    }
    results["meta"] = bench_meta(config=results["scenario"])
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)

    for policy in ("even", "sensitivity"):
        r = results[policy]
        emit(f"fleet_{policy}", r["busy_s"] * 1e6,
             f"{r['tokens_per_s']:.0f}tok/s|{r['j_per_token']*1e3:.2f}mJ/tok"
             f"|{r['preemptions']}preempt|{r['violations']}viol")
    emit("fleet_steering_speedup", 0.0, f"{speedup:.3f}x")
    emit("fleet_j_per_token_ratio", 0.0, f"{j_ratio:.3f}")

    # the acceptance gates: throughput win at equal budget, efficiency
    # no worse, and the preemption demo actually exercised
    assert j_ratio <= J_TOK_TOL, (
        f"sensitivity steering worsened fleet J/token: ratio {j_ratio:.4f}")
    assert results["even"]["preemptions"] == \
        results["sensitivity"]["preemptions"] >= 1, \
        "budget dip failed to exercise the preemption path"
    if min_speedup is not None and speedup < min_speedup:
        raise SystemExit(
            f"fleet steering regression: sensitivity-weighted is only "
            f"{speedup:.3f}x the even split (threshold {min_speedup}x)")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=6)
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail loudly when sensitivity/even fleet "
                         "tokens-per-s falls below this ratio (CI smoke)")
    ap.add_argument("--json-path", default="BENCH_fleet.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.nodes, args.duration, args.min_speedup, args.json_path)


if __name__ == "__main__":
    main()
