"""Traffic/SLO benchmark: autoscaled vs static fleet under a diurnal trace.

The scenario the ``repro.workload`` layer exists for: a million-user
service whose request rate follows a day/night sinusoid with a burst
overlay (``repro.workload.diurnal_trace`` — seed-driven, bit-identical
across runs), served by a fleet of open-loop ``ServeJob``s under one
facility budget.  The SAME trace runs through two fleets:

  static      every serve job admitted at full slot count and kept
              there for the whole run — the classic peak-provisioned
              deployment.  At the diurnal trough the lanes idle but the
              steps keep burning the full batch profile's energy, and
              every node draws its hotel load all day.
  autoscaled  admission control (per-class outstanding bounds keep the
              batch tiers from clogging the interactive path) plus the
              ``Autoscaler``: slot targets track live load (shrinks
              through the proportional-preemption path, grows through
              the scheduler's watt-checked regrow), jobs idle past the
              park threshold hibernate losslessly and their nodes
              power-gate to sleep (zero draw), and queue pressure wakes
              them back up (paying the wake latency) — so the facility
              spends watts where the queue is.

Reported per arm: per-class SLO attainment and p50/p99 latency,
goodput (tokens of deadline-met completions), total energy (serving +
awake-idle hotel load), and goodput-per-joule — the workload lift of
the paper's J/token axis.  Everything runs on the virtual clock:
bit-deterministic, machine-independent (the two-run identity is
asserted below).

Machine-readable results go to ``BENCH_traffic.json``.  Smoke gates
(CI): the autoscaled arm must reach at least ``--min-gain`` (default
1.05) times the static arm's goodput-per-joule, with interactive-class
attainment no worse; the trace must actually exercise sleep/wake; and
two same-seed autoscaled runs must emit identical counters.

  PYTHONPATH=src:. python benchmarks/traffic_slo.py \
      [--nodes 4] [--duration 120] [--seed 0] [--min-gain 1.05]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import bench_meta, emit
from repro.configs.registry import get_model_config
from repro.fleet import ServeJob, SimulatedCluster
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.workload import (AdmissionController, Autoscaler, SLOTracker,
                            WorkloadDriver, diurnal_trace)

#: Serve-token value (the fleet objective unit; the per-request values
#: come from each SLO class on top of this).
SERVE_VALUE = 2.0

#: Awake-idle hotel load per node — the watts power-gating reclaims.
#: The superchip floor is the natural magnitude: an idle node cannot
#: cap away its host + chip idle draw.
IDLE_W = DEFAULT_SUPERCHIP.p_floor

#: Virtual seconds a slept node needs to power back up.
WAKE_S = 2.0


def _make_trace(seed: int, duration: float, base_rps: float):
    return diurnal_trace(seed=seed, until_s=duration, base_rps=base_rps,
                         amplitude=0.9, period_s=duration / 2.0)


def _run_arm(trace, n_nodes: int, duration: float,
             autoscale: bool) -> dict:
    cfg = get_model_config("llama3.2-3b")
    cluster = SimulatedCluster(
        n_nodes=n_nodes, cabinet_size=max(n_nodes // 2, 1),
        policy="sensitivity", idle_w=IDLE_W, wake_latency_s=WAKE_S)
    tracker = SLOTracker(sink=cluster.telemetry)
    driver = WorkloadDriver(
        list(trace), tracker,
        admission=AdmissionController() if autoscale else None,
        autoscaler=Autoscaler(min_slots=1, shrink_frac=0.5,
                              park_after_s=2.0, park_rest_s=2.0,
                              min_running=1, wake_threshold=4)
        if autoscale else None)
    jobs = [ServeJob(f"svc-{i}", cfg, batch=8, prompt=256, new_tokens=64,
                     total_requests=0, decode_chunk=8, open_loop=True,
                     partial=True, migrate=True, value=SERVE_VALUE,
                     slo=tracker)
            for i in range(n_nodes)]
    budget = 0.75 * n_nodes * DEFAULT_SUPERCHIP.p_max
    counters = cluster.run(jobs=jobs, budget=budget, until_s=duration,
                           workload=driver)
    slo = tracker.summary()
    goodput = tracker.goodput_tokens()
    energy = counters["energy_j"] + counters["idle_energy_j"]
    return {
        "goodput_tokens": goodput,
        "energy_j": energy,
        "goodput_per_j": goodput / energy if energy else 0.0,
        "j_per_useful_token": energy / goodput if goodput else 0.0,
        "slo": slo,
        "fleet": counters,
    }


def run(n_nodes: int = 4, duration: float = 120.0, seed: int = 0,
        base_rps: float = 5.0, min_gain: float | None = None,
        json_path: str = "BENCH_traffic.json") -> dict:
    trace = _make_trace(seed, duration, base_rps)
    static = _run_arm(trace, n_nodes, duration, autoscale=False)
    auto = _run_arm(trace, n_nodes, duration, autoscale=True)
    # the determinism contract the whole stack promises: a bit-identical
    # replay of the same seed (trace, scheduling, autoscaling, SLO
    # accounting — everything on the virtual clock)
    auto2 = _run_arm(trace, n_nodes, duration, autoscale=True)

    gain = (auto["goodput_per_j"] / static["goodput_per_j"]
            if static["goodput_per_j"] else float("inf"))
    att_static = static["slo"].get("interactive", {}).get("attainment", 1.0)
    att_auto = auto["slo"].get("interactive", {}).get("attainment", 1.0)
    results = {
        "static": static,
        "autoscaled": auto,
        "goodput_per_j_gain": gain,
        "interactive_attainment_static": att_static,
        "interactive_attainment_autoscaled": att_auto,
        "scenario": {
            "nodes": n_nodes, "duration_s": duration, "seed": seed,
            "base_rps": base_rps, "arrivals": len(trace),
            "idle_w": IDLE_W, "wake_latency_s": WAKE_S,
            "serve_value": SERVE_VALUE,
        },
    }
    results["meta"] = bench_meta(seed=seed, config=results["scenario"])
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)

    for label, r in (("static", static), ("autoscaled", auto)):
        fc = r["fleet"]
        emit(f"traffic_{label}", fc["busy_s"] * 1e6,
             f"{r['goodput_tokens']}goodtok"
             f"|{r['j_per_useful_token']*1e3:.2f}mJ/tok"
             f"|idle={fc['idle_energy_j']:.0f}J"
             f"|sleeps={fc['sleeps']}|wakes={fc['wakes']}"
             f"|qpeak={fc['queue_depth_peak']}")
    for name, s in sorted(auto["slo"].items()):
        emit(f"traffic_slo_{name}", 0.0,
             f"att={s['attainment']:.3f}|p99={s['p99_latency_s']:.2f}s"
             f"|done={s['completed']}|rej={s['rejected']}")
    emit("traffic_goodput_per_j_gain", 0.0, f"{gain:.3f}x")

    # acceptance gates: the diurnal trough must actually power-gate
    # nodes, two same-seed runs must be bit-identical, and elasticity
    # must buy goodput-per-joule without costing interactive attainment
    assert auto["fleet"]["sleeps"] >= 1 and auto["fleet"]["wakes"] >= 1, (
        "autoscaler never exercised the sleep/wake path — scenario broken")
    assert auto == auto2, \
        "same-seed autoscaled runs diverged — determinism broken"
    assert att_auto >= att_static - 1e-9, (
        f"autoscaling cost interactive attainment "
        f"({att_auto:.4f} < {att_static:.4f})")
    assert gain >= 1.0, (
        f"autoscaled arm LOST goodput-per-joule ({gain:.3f}x)")
    if min_gain is not None and gain < min_gain:
        raise SystemExit(
            f"traffic regression: autoscaled goodput-per-joule gain "
            f"{gain:.3f}x below threshold {min_gain}x")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--base-rps", type=float, default=5.0)
    ap.add_argument("--min-gain", type=float, default=None,
                    help="fail loudly when the autoscaled arm's "
                         "goodput-per-joule gain over static falls below "
                         "this factor (CI smoke)")
    ap.add_argument("--json-path", default="BENCH_traffic.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.nodes, args.duration, args.seed, args.base_rps,
        args.min_gain, args.json_path)


if __name__ == "__main__":
    main()
