"""Traffic/SLO benchmark: autoscaled vs static fleet under a diurnal trace.

The scenario the ``repro.workload`` layer exists for: a million-user
service whose request rate follows a day/night sinusoid with a burst
overlay (``repro.workload.diurnal_trace`` — seed-driven, bit-identical
across runs), served by a fleet of open-loop ``ServeJob``s under one
facility budget.  The SAME trace runs through two fleets:

  static      every serve job admitted at full slot count and kept
              there for the whole run — the classic peak-provisioned
              deployment.  At the diurnal trough the lanes idle but the
              steps keep burning the full batch profile's energy, and
              every node draws its hotel load all day.
  autoscaled  admission control (per-class outstanding bounds keep the
              batch tiers from clogging the interactive path) plus the
              ``Autoscaler``: slot targets track live load (shrinks
              through the proportional-preemption path, grows through
              the scheduler's watt-checked regrow), jobs idle past the
              park threshold hibernate losslessly and their nodes
              power-gate to sleep (zero draw), and queue pressure wakes
              them back up (paying the wake latency) — so the facility
              spends watts where the queue is.

Reported per arm: per-class SLO attainment and p50/p99 latency,
goodput (tokens of deadline-met completions), total energy (serving +
awake-idle hotel load), and goodput-per-joule — the workload lift of
the paper's J/token axis.  Everything runs on the virtual clock:
bit-deterministic, machine-independent (the two-run identity is
asserted below).

A third, much smaller ``engine`` arm swaps the modeled ServeJobs for
REAL ``ServeEngine``-backed ones (paged KV cache): a short clamped
trace is offered open-loop through the same WorkloadDriver + admission
+ autoscaler stack, arrivals become synthesized ``Request``s submitted
to live engines mid-flight, and completions clock real arrival→finish
latency into the SLO tracker.  It proves the whole workload stack runs
end-to-end on actual model compute, not just the roofline model.

Machine-readable results go to ``BENCH_traffic.json``.  Smoke gates
(CI): the autoscaled arm must reach at least ``--min-gain`` (default
1.05) times the static arm's goodput-per-joule, with interactive-class
attainment no worse; the trace must actually exercise sleep/wake; two
same-seed autoscaled runs must emit identical counters; and the engine
arm must complete at least one real request.

  PYTHONPATH=src:. python benchmarks/traffic_slo.py \
      [--nodes 4] [--duration 120] [--seed 0] [--min-gain 1.05] \
      [--skip-engine-arm]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import bench_meta, emit
from repro.configs.registry import get_model_config
from repro.fleet import ServeJob, SimulatedCluster
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.workload import (AdmissionController, Autoscaler, SLOTracker,
                            WorkloadDriver, diurnal_trace)

#: Serve-token value (the fleet objective unit; the per-request values
#: come from each SLO class on top of this).
SERVE_VALUE = 2.0

#: Awake-idle hotel load per node — the watts power-gating reclaims.
#: The superchip floor is the natural magnitude: an idle node cannot
#: cap away its host + chip idle draw.
IDLE_W = DEFAULT_SUPERCHIP.p_floor

#: Virtual seconds a slept node needs to power back up.
WAKE_S = 2.0


def _make_trace(seed: int, duration: float, base_rps: float):
    return diurnal_trace(seed=seed, until_s=duration, base_rps=base_rps,
                         amplitude=0.9, period_s=duration / 2.0)


def _run_arm(trace, n_nodes: int, duration: float,
             autoscale: bool) -> dict:
    cfg = get_model_config("llama3.2-3b")
    cluster = SimulatedCluster(
        n_nodes=n_nodes, cabinet_size=max(n_nodes // 2, 1),
        policy="sensitivity", idle_w=IDLE_W, wake_latency_s=WAKE_S)
    tracker = SLOTracker(sink=cluster.telemetry)
    driver = WorkloadDriver(
        list(trace), tracker,
        admission=AdmissionController() if autoscale else None,
        autoscaler=Autoscaler(min_slots=1, shrink_frac=0.5,
                              park_after_s=2.0, park_rest_s=2.0,
                              min_running=1, wake_threshold=4)
        if autoscale else None)
    jobs = [ServeJob(f"svc-{i}", cfg, batch=8, prompt=256, new_tokens=64,
                     total_requests=0, decode_chunk=8, open_loop=True,
                     partial=True, migrate=True, value=SERVE_VALUE,
                     slo=tracker)
            for i in range(n_nodes)]
    budget = 0.75 * n_nodes * DEFAULT_SUPERCHIP.p_max
    counters = cluster.run(jobs=jobs, budget=budget, until_s=duration,
                           workload=driver)
    slo = tracker.summary()
    goodput = tracker.goodput_tokens()
    energy = counters["energy_j"] + counters["idle_energy_j"]
    return {
        "goodput_tokens": goodput,
        "energy_j": energy,
        "goodput_per_j": goodput / energy if energy else 0.0,
        "j_per_useful_token": energy / goodput if goodput else 0.0,
        "slo": slo,
        "fleet": counters,
    }


#: Engine-arm scale: real model compute, so the fleet and trace stay
#: tiny — enough to exercise submit/admission/autoscale, not to profile.
ENGINE_NODES = 2
ENGINE_DURATION_S = 20.0
ENGINE_RPS = 0.4
ENGINE_MAX_SEQ = 32
ENGINE_PROMPT_CAP = 24
ENGINE_OUTPUT_CAP = 6


def _run_engine_arm(seed: int) -> dict:
    """Real-``ServeEngine`` open-loop fleet (paged KV cache) under the
    same WorkloadDriver/admission/autoscaler stack as the modeled arms.
    Trace lengths are clamped to the engines' tiny ``max_seq``."""
    import dataclasses

    import jax

    from repro.configs.base import reduced
    from repro.configs.registry import get_run_config
    from repro.models import lm
    from repro.models.layers import Ctx
    from repro.models.params import init_params
    from repro.serving.engine import ServeEngine
    from repro.sharding import RULE_SETS

    arch = "llama3.2-3b"
    cfg = reduced(get_model_config(arch))
    run_cfg = get_run_config(arch, remat="none", logits_chunk=64)
    ctx = Ctx(run_cfg, RULE_SETS[run_cfg.serve_rules_name], None)
    params = init_params(lm.model_decls(cfg), jax.random.PRNGKey(seed))

    cluster = SimulatedCluster(
        n_nodes=ENGINE_NODES, cabinet_size=1, policy="sensitivity",
        idle_w=IDLE_W, wake_latency_s=WAKE_S)
    tracker = SLOTracker(sink=cluster.telemetry)
    trace = [dataclasses.replace(
                 e, prompt_len=min(e.prompt_len, ENGINE_PROMPT_CAP),
                 output_len=min(max(e.output_len, 1), ENGINE_OUTPUT_CAP))
             for e in diurnal_trace(seed=seed, until_s=ENGINE_DURATION_S,
                                    base_rps=ENGINE_RPS, amplitude=0.5,
                                    period_s=ENGINE_DURATION_S)]
    driver = WorkloadDriver(
        trace, tracker, admission=AdmissionController(),
        autoscaler=Autoscaler(min_slots=1, shrink_frac=0.5,
                              park_after_s=4.0, park_rest_s=2.0,
                              min_running=1, wake_threshold=4))
    # NOTE: batch/prompt/new_tokens parameterize the MODELED roofline
    # step cost (what paces the virtual clock — keep the modeled arms'
    # realistic profile, or a node quantum decays into millions of
    # micro-steps); the actual compute shape is the engine's.
    jobs = [ServeJob(
                f"eng-{i}", cfg, batch=8, prompt=256, new_tokens=64,
                total_requests=0,
                decode_chunk=8, open_loop=True, partial=True,
                migrate=True, value=SERVE_VALUE, slo=tracker,
                engine=ServeEngine(cfg, run_cfg, ctx, params,
                                   batch_size=4, max_seq=ENGINE_MAX_SEQ,
                                   prefill_chunk=8, decode_chunk=4,
                                   paged=True, block_size=8))
            for i in range(ENGINE_NODES)]
    budget = 0.75 * ENGINE_NODES * DEFAULT_SUPERCHIP.p_max
    counters = cluster.run(jobs=jobs, budget=budget,
                           until_s=ENGINE_DURATION_S, workload=driver)
    slo = tracker.summary()
    completed = sum(c["completed"] for c in slo.values())
    return {
        "arrivals": len(trace),
        "completed": completed,
        "generated_tokens": sum(j.emitted for j in jobs),
        "goodput_tokens": tracker.goodput_tokens(),
        "adoptions": counters["adoptions"],
        "sleeps": counters["sleeps"],
        "wakes": counters["wakes"],
        "queue_depth_peak": counters["queue_depth_peak"],
        "slo": slo,
    }


def run(n_nodes: int = 4, duration: float = 120.0, seed: int = 0,
        base_rps: float = 5.0, min_gain: float | None = None,
        json_path: str = "BENCH_traffic.json",
        engine_arm: bool = True) -> dict:
    trace = _make_trace(seed, duration, base_rps)
    static = _run_arm(trace, n_nodes, duration, autoscale=False)
    auto = _run_arm(trace, n_nodes, duration, autoscale=True)
    # the determinism contract the whole stack promises: a bit-identical
    # replay of the same seed (trace, scheduling, autoscaling, SLO
    # accounting — everything on the virtual clock)
    auto2 = _run_arm(trace, n_nodes, duration, autoscale=True)

    gain = (auto["goodput_per_j"] / static["goodput_per_j"]
            if static["goodput_per_j"] else float("inf"))
    att_static = static["slo"].get("interactive", {}).get("attainment", 1.0)
    att_auto = auto["slo"].get("interactive", {}).get("attainment", 1.0)
    results = {
        "static": static,
        "autoscaled": auto,
        "goodput_per_j_gain": gain,
        "interactive_attainment_static": att_static,
        "interactive_attainment_autoscaled": att_auto,
        "scenario": {
            "nodes": n_nodes, "duration_s": duration, "seed": seed,
            "base_rps": base_rps, "arrivals": len(trace),
            "idle_w": IDLE_W, "wake_latency_s": WAKE_S,
            "serve_value": SERVE_VALUE,
        },
    }
    if engine_arm:
        eng = _run_engine_arm(seed)
        results["engine"] = eng
        results["scenario"]["engine_arm"] = {
            "nodes": ENGINE_NODES, "duration_s": ENGINE_DURATION_S,
            "base_rps": ENGINE_RPS, "max_seq": ENGINE_MAX_SEQ,
        }
    results["meta"] = bench_meta(seed=seed, config=results["scenario"])
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)

    for label, r in (("static", static), ("autoscaled", auto)):
        fc = r["fleet"]
        emit(f"traffic_{label}", fc["busy_s"] * 1e6,
             f"{r['goodput_tokens']}goodtok"
             f"|{r['j_per_useful_token']*1e3:.2f}mJ/tok"
             f"|idle={fc['idle_energy_j']:.0f}J"
             f"|sleeps={fc['sleeps']}|wakes={fc['wakes']}"
             f"|qpeak={fc['queue_depth_peak']}")
    for name, s in sorted(auto["slo"].items()):
        emit(f"traffic_slo_{name}", 0.0,
             f"att={s['attainment']:.3f}|p99={s['p99_latency_s']:.2f}s"
             f"|done={s['completed']}|rej={s['rejected']}")
    emit("traffic_goodput_per_j_gain", 0.0, f"{gain:.3f}x")
    if engine_arm:
        eng = results["engine"]
        emit("traffic_engine", 0.0,
             f"{eng['completed']}/{eng['arrivals']}done"
             f"|{eng['generated_tokens']}tok"
             f"|adopt={eng['adoptions']}|qpeak={eng['queue_depth_peak']}")
        # the real-engine fleet must actually serve traffic end to end
        assert eng["completed"] >= 1, (
            "engine arm completed no requests — open-loop submit path "
            "broken")
        assert eng["generated_tokens"] > 0

    # acceptance gates: the diurnal trough must actually power-gate
    # nodes, two same-seed runs must be bit-identical, and elasticity
    # must buy goodput-per-joule without costing interactive attainment
    assert auto["fleet"]["sleeps"] >= 1 and auto["fleet"]["wakes"] >= 1, (
        "autoscaler never exercised the sleep/wake path — scenario broken")
    assert auto == auto2, \
        "same-seed autoscaled runs diverged — determinism broken"
    assert att_auto >= att_static - 1e-9, (
        f"autoscaling cost interactive attainment "
        f"({att_auto:.4f} < {att_static:.4f})")
    assert gain >= 1.0, (
        f"autoscaled arm LOST goodput-per-joule ({gain:.3f}x)")
    if min_gain is not None and gain < min_gain:
        raise SystemExit(
            f"traffic regression: autoscaled goodput-per-joule gain "
            f"{gain:.3f}x below threshold {min_gain}x")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--base-rps", type=float, default=5.0)
    ap.add_argument("--min-gain", type=float, default=None,
                    help="fail loudly when the autoscaled arm's "
                         "goodput-per-joule gain over static falls below "
                         "this factor (CI smoke)")
    ap.add_argument("--json-path", default="BENCH_traffic.json")
    ap.add_argument("--skip-engine-arm", action="store_true",
                    help="skip the real-ServeEngine arm (runs actual "
                         "model compute)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.nodes, args.duration, args.seed, args.base_rps,
        args.min_gain, args.json_path, engine_arm=not args.skip_engine_arm)


if __name__ == "__main__":
    main()
