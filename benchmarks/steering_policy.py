"""Beyond-paper benchmark: the power-steering policies applied to the
whole application (the 'future work' of paper section 4/5), now through
``repro.power.PowerManager``.

Compares four policies on the LSMS-analogue phase sequence:
  uncapped      default max power
  app_static    one application-wide cap chosen by SED over the total
  per_task      PowerManager's per-task caps (SED and ED), including
                cap-transition overhead
  adaptive      online re-decide: the manager starts from a STALE profile
                (zgemm64 mis-profiled as memory-bound), observes the true
                workload phase by phase (with round-robin cap probing),
                refines its TaskTable and re-decides — converging back to
                the true per-task schedule

Validates the paper's headline (per-task capping beats application-wide
tuning) and the adaptive extension (re-deciding recovers from drift)."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, timed
from repro.core import simulate_task
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.models.lsms import paper_calibrated_tasks, scf_phase_sequence
from repro.power import CapSchedule, PowerManager, SimulatedBackend


def _app_totals(phases, cap_for) -> tuple[float, float, int]:
    """Execute the phase sequence under a per-phase cap policy."""
    t = e = 0.0
    transitions = 0
    prev = None
    for ph in phases:
        cap = cap_for(ph.name)
        if prev is not None and cap != prev:
            transitions += 1
        prev = cap
        m = simulate_task(ph, cap)
        t += m.runtime
        e += m.energy
    return t, e, transitions


def _stale_tasks(tasks):
    """A drifted profile: the dominant zgemm64 mis-characterized as
    memory-bound, so a schedule decided from it caps the true
    compute-bound task far too low."""
    out = []
    for t in tasks:
        if t.name == "zgemm_ts64":
            t = dataclasses.replace(t, flops=t.flops * 0.3,
                                    hbm_bytes=t.hbm_bytes * 6.0)
        out.append(t)
    return out


def _adaptive(tasks, phases, rounds: int = 40) -> tuple[CapSchedule,
                                                        CapSchedule]:
    """Run the online loop: stale table in, true observations + periodic
    re-decides, converged schedule out.  Returns (stale, converged)."""
    stale_table = SimulatedBackend().sweep(_stale_tasks(tasks))
    pm = PowerManager(stale_table, metric="sed", redecide_every=16,
                      ema_alpha=0.7, explore_every=2)
    stale = CapSchedule(dict(pm.schedule.caps), pm.schedule.default_cap)
    for _ in range(rounds):
        for ph in phases:
            cap = pm.next_cap(ph.name)
            m = simulate_task(ph, cap)           # ground truth telemetry
            pm.observe(ph.name, m.runtime, m.energy, cap=cap,
                       clock_fraction=m.clock_fraction)
    pm.redecide()
    return stale, pm.schedule


def run() -> dict:
    spec = DEFAULT_SUPERCHIP
    tasks = paper_calibrated_tasks()
    phases = scf_phase_sequence()

    def compute():
        return {m: PowerManager(tasks=tasks, metric=m).schedule
                for m in ("sed", "ed")}

    schedules, us = timed(compute)

    t0, e0, _ = _app_totals(phases, lambda _: spec.p_default)

    # best single application-wide cap by SED over app totals
    best_cap, best_sed = None, -1.0
    for cap in spec.cap_sweep():
        t, e, _ = _app_totals(phases, lambda _, c=cap: c)
        sed = (t0 * e0) / (t * e)
        if sed > best_sed:
            best_sed, best_cap = sed, cap
    t_app, e_app, _ = _app_totals(phases, lambda _, c=best_cap: c)

    out = {"uncapped": (t0, e0)}
    for m, sched in schedules.items():
        t, e, trans = _app_totals(phases, sched.cap_for)
        dt_o, de_o = sched.overhead([p.name for p in phases])
        t, e = t + dt_o, e + de_o
        out[m] = (t, e)
        emit(f"steering_{m}_energy_saving_pct", us,
             round((e0 - e) / e0 * 100, 2))
        emit(f"steering_{m}_runtime_increase_pct", us,
             round((t - t0) / t0 * 100, 2))
        emit(f"steering_{m}_cap_transitions", us, trans)
    emit("steering_app_static_cap_w", us, best_cap)
    emit("steering_app_static_energy_saving_pct", us,
         round((e0 - e_app) / e0 * 100, 2))

    # policy 3: adaptive (online re-decide) from a stale profile
    stale_sched, adapted_sched = _adaptive(tasks, phases)
    for name, sched in (("stale", stale_sched), ("adaptive", adapted_sched)):
        t, e, _ = _app_totals(phases, sched.cap_for)
        dt_o, de_o = sched.overhead([p.name for p in phases])
        out[name] = (t + dt_o, e + de_o)
    emit("steering_adaptive_energy_saving_pct", us,
         round((e0 - out["adaptive"][1]) / e0 * 100, 2))
    emit("steering_adaptive_runtime_increase_pct", us,
         round((out["adaptive"][0] - t0) / t0 * 100, 2))
    emit("steering_stale_profile_energy_saving_pct", us,
         round((e0 - out["stale"][1]) / e0 * 100, 2))

    # paper headline: task-level capping beats application-wide tuning —
    # compared on the optimization objective itself (the energy-delay
    # product both levels optimize), more degrees of freedom must win.
    edp_task = (t0 * e0) / (out["sed"][0] * out["sed"][1])
    edp_app = (t0 * e0) / (t_app * e_app)
    assert edp_task >= edp_app - 1e-6, (edp_task, edp_app)
    emit("steering_per_task_edp_gain", us, round(edp_task, 4))
    emit("steering_app_wide_edp_gain", us, round(edp_app, 4))
    # adaptive extension: online re-decides must recover (most of) the gap
    # the stale profile opened against the true per-task schedule
    edp_stale = (t0 * e0) / (out["stale"][0] * out["stale"][1])
    edp_adapt = (t0 * e0) / (out["adaptive"][0] * out["adaptive"][1])
    assert edp_adapt >= edp_stale - 1e-6, (edp_adapt, edp_stale)
    assert edp_adapt >= 0.95 * edp_task, (edp_adapt, edp_task)
    emit("steering_adaptive_edp_gain", us, round(edp_adapt, 4))
    emit("steering_stale_profile_edp_gain", us, round(edp_stale, 4))
    # and on raw energy at equal-objective picks, the ED policy saves more
    # than the best app-wide static cap
    ed_saving = (e0 - out["ed"][1]) / e0
    emit("steering_ed_beats_app_wide_energy", us,
         bool(ed_saving > (e0 - e_app) / e0))
    return {"schedules": schedules, "totals": out}


if __name__ == "__main__":
    run()
