"""Beyond-paper benchmark: the adaptive power-steering controller applied to
the whole application (the 'future work' of paper section 4/5).

Compares three policies on the LSMS-analogue phase sequence:
  uncapped      default max power
  app_static    one application-wide cap chosen by SED over the total
  per_task      the controller's per-task caps (SED and ED), including
                cap-transition overhead
Validates the paper's headline: per-task capping beats application-wide
tuning."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import (PowerSteeringController, SteeringGoal, measure_sweep,
                        simulate_task)
from repro.core.tasks import Task, TaskTable
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.models.lsms import paper_calibrated_tasks, scf_phase_sequence


def _app_totals(phases, cap_for) -> tuple[float, float, int]:
    """Execute the phase sequence under a per-phase cap policy."""
    t = e = 0.0
    transitions = 0
    prev = None
    for ph in phases:
        cap = cap_for(ph.name)
        if prev is not None and cap != prev:
            transitions += 1
        prev = cap
        m = simulate_task(ph, cap)
        t += m.runtime
        e += m.energy
    return t, e, transitions


def run() -> dict:
    spec = DEFAULT_SUPERCHIP
    tasks = paper_calibrated_tasks()
    phases = scf_phase_sequence()
    table = measure_sweep(tasks)
    ctrl = PowerSteeringController(spec)

    def compute():
        return {m: ctrl.schedule(table, SteeringGoal(metric=m))
                for m in ("sed", "ed")}

    schedules, us = timed(compute)

    t0, e0, _ = _app_totals(phases, lambda _: spec.p_default)

    # best single application-wide cap by SED over app totals
    best_cap, best_sed = None, -1.0
    for cap in spec.cap_sweep():
        t, e, _ = _app_totals(phases, lambda _, c=cap: c)
        sed = (t0 * e0) / (t * e)
        if sed > best_sed:
            best_sed, best_cap = sed, cap
    t_app, e_app, _ = _app_totals(phases, lambda _, c=best_cap: c)

    out = {"uncapped": (t0, e0)}
    for m, sched in schedules.items():
        t, e, trans = _app_totals(phases, sched.cap_for)
        dt_o, de_o = sched.overhead([p.name for p in phases])
        t, e = t + dt_o, e + de_o
        out[m] = (t, e)
        emit(f"steering_{m}_energy_saving_pct", us,
             round((e0 - e) / e0 * 100, 2))
        emit(f"steering_{m}_runtime_increase_pct", us,
             round((t - t0) / t0 * 100, 2))
        emit(f"steering_{m}_cap_transitions", us, trans)
    emit("steering_app_static_cap_w", us, best_cap)
    emit("steering_app_static_energy_saving_pct", us,
         round((e0 - e_app) / e0 * 100, 2))

    # paper headline: task-level capping beats application-wide tuning —
    # compared on the optimization objective itself (the energy-delay
    # product both levels optimize), more degrees of freedom must win.
    edp_task = (t0 * e0) / (out["sed"][0] * out["sed"][1])
    edp_app = (t0 * e0) / (t_app * e_app)
    assert edp_task >= edp_app - 1e-6, (edp_task, edp_app)
    emit("steering_per_task_edp_gain", us, round(edp_task, 4))
    emit("steering_app_wide_edp_gain", us, round(edp_app, 4))
    # and on raw energy at equal-objective picks, the ED policy saves more
    # than the best app-wide static cap
    ed_saving = (e0 - out["ed"][1]) / e0
    emit("steering_ed_beats_app_wide_energy", us,
         bool(ed_saving > (e0 - e_app) / e0))
    return {"schedules": schedules, "totals": out}


if __name__ == "__main__":
    run()
