"""Pareto-steering benchmark: learned-curve ED steering vs scalar steering.

The headline for ``repro.fleet.pareto``: the SAME seed-driven diurnal
serving trace (``repro.workload.diurnal_trace``) runs through two fleets
under the same facility budget —

  scalar   ``policy="sensitivity"`` — the incumbent marginal-perf-per-
           watt transfer loop.  Open-loop serve lanes run their steps
           continuously, so every node is granted up to its (prefill-
           driven) full request and burns near-peak watts all day.
  pareto   ``policy="pareto"`` — each node's grant is CEILINGED at its
           Euclidean-distance Pareto point on curves fitted online from
           its own telemetry (J/token vs s/token, the paper's Global
           Criterion selection lifted from cap tables to grant space),
           with a small exploration budget probing off-curve caps.

Reported per arm: per-class SLO attainment, goodput (tokens of
deadline-met completions), total energy (serving + awake-idle hotel
load) and goodput-per-joule.  Everything runs on the virtual clock —
bit-deterministic, machine-independent (two same-seed pareto runs are
asserted identical below).

Machine-readable results go to ``BENCH_pareto.json``.  Smoke gates (CI):
the pareto arm must reach at least ``--min-gain`` (default 1.0) times
the scalar arm's goodput-per-joule with interactive-class attainment no
worse; curve fitting must actually engage (ready nodes, probes); and two
same-seed pareto runs must emit identical counters.

  PYTHONPATH=src:. python benchmarks/pareto_fleet.py \
      [--nodes 4] [--duration 120] [--seed 0] [--min-gain 1.0]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import bench_meta, emit
from repro.configs.registry import get_model_config
from repro.fleet import ServeJob, SimulatedCluster
from repro.hw.tpu import DEFAULT_SUPERCHIP
from repro.workload import SLOTracker, WorkloadDriver, diurnal_trace

#: Serve-token value (the fleet objective unit).
SERVE_VALUE = 2.0

#: Awake-idle hotel load per node (an idle node cannot cap away its
#: host + chip idle draw).
IDLE_W = DEFAULT_SUPERCHIP.p_floor

#: Virtual seconds a slept node needs to power back up.
WAKE_S = 2.0

#: Pareto-arm exploration rate: expected probe grants per node per
#: quantum.  Enough to keep every curve's support fresh over a
#: benchmark-length run without visibly denting goodput.
EXPLORE_BUDGET = 0.1


def _make_trace(seed: int, duration: float, base_rps: float):
    return diurnal_trace(seed=seed, until_s=duration, base_rps=base_rps,
                         amplitude=0.9, period_s=duration / 2.0)


def _run_arm(trace, n_nodes: int, duration: float, policy: str) -> dict:
    cfg = get_model_config("llama3.2-3b")
    cluster = SimulatedCluster(
        n_nodes=n_nodes, cabinet_size=max(n_nodes // 2, 1),
        policy=policy, idle_w=IDLE_W, wake_latency_s=WAKE_S,
        explore_budget=EXPLORE_BUDGET)
    tracker = SLOTracker(sink=cluster.telemetry)
    driver = WorkloadDriver(list(trace), tracker)
    jobs = [ServeJob(f"svc-{i}", cfg, batch=8, prompt=256, new_tokens=64,
                     total_requests=0, decode_chunk=8, open_loop=True,
                     partial=True, migrate=True, value=SERVE_VALUE,
                     slo=tracker)
            for i in range(n_nodes)]
    budget = 0.75 * n_nodes * DEFAULT_SUPERCHIP.p_max
    counters = cluster.run(jobs=jobs, budget=budget, until_s=duration,
                           workload=driver)
    slo = tracker.summary()
    goodput = tracker.goodput_tokens()
    energy = counters["energy_j"] + counters["idle_energy_j"]
    return {
        "goodput_tokens": goodput,
        "energy_j": energy,
        "goodput_per_j": goodput / energy if energy else 0.0,
        "j_per_useful_token": energy / goodput if goodput else 0.0,
        "slo": slo,
        "fleet": counters,
    }


def run(n_nodes: int = 4, duration: float = 120.0, seed: int = 0,
        base_rps: float = 5.0, min_gain: float | None = None,
        json_path: str = "BENCH_pareto.json") -> dict:
    trace = _make_trace(seed, duration, base_rps)
    scalar = _run_arm(trace, n_nodes, duration, policy="sensitivity")
    pareto = _run_arm(trace, n_nodes, duration, policy="pareto")
    # the determinism contract: bit-identical same-seed replay of the
    # whole stack — trace, curve fitting, exploration, ED targets, SLO
    # accounting, everything on the virtual clock
    pareto2 = _run_arm(trace, n_nodes, duration, policy="pareto")

    gain = (pareto["goodput_per_j"] / scalar["goodput_per_j"]
            if scalar["goodput_per_j"] else float("inf"))
    att_scalar = scalar["slo"].get("interactive", {}).get("attainment", 1.0)
    att_pareto = pareto["slo"].get("interactive", {}).get("attainment", 1.0)
    results = {
        "scalar": scalar,
        "pareto": pareto,
        "goodput_per_j_gain": gain,
        "interactive_attainment_scalar": att_scalar,
        "interactive_attainment_pareto": att_pareto,
        "scenario": {
            "nodes": n_nodes, "duration_s": duration, "seed": seed,
            "base_rps": base_rps, "arrivals": len(trace),
            "idle_w": IDLE_W, "wake_latency_s": WAKE_S,
            "serve_value": SERVE_VALUE,
            "explore_budget": EXPLORE_BUDGET,
        },
    }
    results["meta"] = bench_meta(seed=seed, config=results["scenario"])
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)

    for label, r in (("scalar", scalar), ("pareto", pareto)):
        fc = r["fleet"]
        extra = (f"|curves={fc['curve_ready_nodes']}rdy"
                 f"@{fc['curve_confidence']:.2f}"
                 f"|probes={fc['explore_probes']}"
                 if label == "pareto" else "")
        emit(f"pareto_{label}", fc["busy_s"] * 1e6,
             f"{r['goodput_tokens']}goodtok"
             f"|{r['j_per_useful_token']*1e3:.2f}mJ/tok"
             f"|{r['energy_j']:.0f}J{extra}")
    for name, s in sorted(pareto["slo"].items()):
        emit(f"pareto_slo_{name}", 0.0,
             f"att={s['attainment']:.3f}|p99={s['p99_latency_s']:.2f}s"
             f"|done={s['completed']}")
    emit("pareto_goodput_per_j_gain", 0.0, f"{gain:.3f}x")

    # acceptance gates: curve learning must actually engage, two
    # same-seed runs must be bit-identical, and the Pareto ceilings must
    # buy goodput-per-joule without costing interactive attainment
    pf = pareto["fleet"]
    assert pf["curve_samples"] > 0 and pf["curve_ready_nodes"] > 0, (
        "pareto arm never fit a curve — learning path broken")
    assert pf["explore_probes"] > 0, (
        "pareto arm never probed off-curve — exploration path broken")
    assert pareto == pareto2, \
        "same-seed pareto runs diverged — determinism broken"
    assert att_pareto >= att_scalar - 1e-9, (
        f"pareto steering cost interactive attainment "
        f"({att_pareto:.4f} < {att_scalar:.4f})")
    assert gain >= 1.0, (
        f"pareto arm LOST goodput-per-joule ({gain:.3f}x)")
    if min_gain is not None and gain < min_gain:
        raise SystemExit(
            f"pareto regression: goodput-per-joule gain {gain:.3f}x "
            f"below threshold {min_gain}x")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--base-rps", type=float, default=5.0)
    ap.add_argument("--min-gain", type=float, default=None,
                    help="fail loudly when the pareto arm's goodput-per-"
                         "joule gain over scalar falls below this factor "
                         "(CI smoke)")
    ap.add_argument("--json-path", default="BENCH_pareto.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.nodes, args.duration, args.seed, args.base_rps,
        args.min_gain, args.json_path)


if __name__ == "__main__":
    main()
