"""Migration benchmark: preemption as drop, drain, proportional shed, int8.

The fleet scenario the portable-slot-state stack exists for: a facility
budget that repeatedly dips and squeezes (grid events / thermal
excursions), preempting latency-sensitive serving work, then recovering
— sometimes all at once, sometimes a few watts at a time.  The same
mixed queue (two high-value serve jobs, two background training jobs)
runs through ``repro.fleet.SimulatedCluster`` FOUR times at the SAME
budget trace:

  drop      ServeJob(migrate=False) — the PR-3 baseline: a preempted
            serving stint destroys its in-flight batch; the tokens are
            refunded and regenerated after resume (double-paid work)
  migrate   ServeJob(migrate=True) — the PR-4 baseline: preemption
            drains every slot into a portable SlotSnapshot; the job
            re-queues WITH its snapshots and resumes origin-affine
            (its own node when free, else the cheapest link), the
            cluster charging the transfer at the LINK bandwidth on the
            receiving node's clock
  partial   ServeJob(partial=True) — proportional preemption: a budget
            squeeze sheds only the slots it strands
            (ceil(deficit / margin-per-slot), fewest remaining tokens
            first), survivors keep serving, and parked slots re-admit
            a few watts at a time as the budget staircases back —
            instead of waiting for a whole node's worth of headroom
  int8      ServeJob(snapshot_int8=True) — the migrate arm with
            snapshot payloads int8-compressed at rest: migration bytes
            (and wire seconds) roughly halve at a bounded parity cost

and reports per mode: USEFUL serve tokens (delivered once, never
redone), fleet tokens/s, modeled J per useful serve token, request
latency p50/p99 (virtual clock, per-stream completion), dropped vs
migrated vs parked tokens, and the migration count/bytes/seconds.
Everything runs on the virtual clock — bit-deterministic,
machine-independent.

The budget trace has two regimes: two DEEP DIPS below any node's floor
(everything preempts; on recovery the quick-restart training jobs grab
the first free nodes, so the snapshot-carrying serve jobs must migrate
— origin-affine, cheapest-link), then two SQUEEZES that strand only
half of one serve batch's useful margin, recovering in watt-sized
steps (the regime where proportional preemption pays).

Machine-readable results go to ``BENCH_migrate.json``.  Smoke gates
(CI): migration must recover at least ``--min-recovery`` (default 0.5)
of the tokens the baseline drops and serve no fewer useful tokens;
int8 must halve migration bytes within +-10%; partial drains must
serve at least the migrate arm's useful tokens at LOWER p99.

  PYTHONPATH=src:. python benchmarks/migration.py \
      [--nodes 4] [--duration 40] [--min-recovery 0.5]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import bench_meta, emit
from repro.configs.registry import get_model_config
from repro.fleet import ServeJob, SimulatedCluster, TrainJob
from repro.fleet.cluster import USEFUL_MARGIN_W
from repro.hw.tpu import DEFAULT_SUPERCHIP

#: Token value of a serve token relative to a background train token in
#: the fleet objective (and the preemption order).
SERVE_VALUE = 4.0

#: Restart backoffs: a train job restarts from its checkpoint almost
#: immediately; a serve stint pays for drain + state streaming setup.
#: The asymmetry is what lets training reclaim free nodes first after a
#: deep dip — forcing the snapshot-carrying serve jobs through the
#: origin-affine / cheapest-link migration path this benchmark measures.
TRAIN_BACKOFF_S = 0.05
SERVE_BACKOFF_S = 2.5


def _jobs(n_nodes: int, migrate: bool, partial: bool = False,
          snapshot_int8: bool = False) -> list:
    """Half serving (high value), half training (background)."""
    llama = get_model_config("llama3.2-3b")
    mamba = get_model_config("mamba2-370m")
    jobs = []
    for i in range(n_nodes):
        if i % 2 == 0:
            jobs.append(ServeJob(
                f"serve-{i}", llama, batch=32, prompt=1024, new_tokens=256,
                total_requests=10**9, decode_chunk=32, value=SERVE_VALUE,
                migrate=migrate, partial=partial,
                snapshot_int8=snapshot_int8, max_restarts=64,
                backoff_s=SERVE_BACKOFF_S))
        else:
            jobs.append(TrainJob(
                f"train-{i}", mamba if i % 4 == 3 else llama, batch=8,
                seq=512, total_steps=10**9, max_restarts=64,
                backoff_s=TRAIN_BACKOFF_S))
    return jobs


def _budget_trace(n_nodes: int, duration: float) -> list:
    """Two regimes against the same fleet:

      * deep dips below any node's floor — everything preempts; the
        recoveries force cross-node snapshot migrations (trains restart
        first and take the lowest-numbered nodes);
      * squeezes to ``2*min_node_w - margin/2`` — with the trains shed,
        the two serve nodes are short exactly half of one batch's
        useful margin, so a partial-capable job sheds
        ``ceil(deficit / (margin / batch))`` slots and keeps serving;
        recovery arrives in margin/4-sized steps that re-admit parked
        slots long before a whole node's worth of headroom exists.
    """
    p = n_nodes * DEFAULT_SUPERCHIP.p_max
    hi = 0.75 * p
    dip = 0.5 * DEFAULT_SUPERCHIP.p_floor
    min_w = DEFAULT_SUPERCHIP.p_floor + USEFUL_MARGIN_W
    sq0 = 2 * min_w - USEFUL_MARGIN_W / 2    # strands half a batch
    sq1 = 2 * min_w - USEFUL_MARGIN_W / 4    # half the parked return
    sq2 = 2 * min_w                          # full batch floats again
    legs = [
        (0.00, hi),
        (0.10, dip), (0.15, hi),             # dip 1 -> migrations
        (0.30, dip), (0.35, hi),             # dip 2
        (0.50, sq0), (0.60, sq1), (0.65, sq2), (0.70, hi),   # squeeze 1
        (0.82, sq0), (0.90, sq1), (0.95, sq2),               # squeeze 2
    ]
    return [(f * duration, w) for f, w in legs]


def _latency_pcts(jobs) -> tuple[float, float]:
    lats = sorted(l for j in jobs if j.kind == "serve"
                  for l in j.request_latencies)
    if not lats:
        return 0.0, 0.0
    p50 = lats[len(lats) // 2]
    p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
    return p50, p99


ARMS = (
    ("drop", dict(migrate=False)),
    ("migrate", dict(migrate=True)),
    ("partial", dict(migrate=True, partial=True)),
    ("int8", dict(migrate=True, snapshot_int8=True)),
)


def run(n_nodes: int = 4, duration: float = 40.0,
        min_recovery: float | None = None,
        json_path: str = "BENCH_migrate.json") -> dict:
    trace = _budget_trace(n_nodes, duration)
    results: dict = {}
    for label, kw in ARMS:
        jobs = _jobs(n_nodes, **kw)
        cluster = SimulatedCluster(n_nodes=n_nodes,
                                   cabinet_size=max(n_nodes // 2, 1),
                                   policy="sensitivity")
        counters = cluster.run(jobs=jobs, budget=trace, until_s=duration)
        p50, p99 = _latency_pcts(jobs)
        useful = sum(j.emitted for j in jobs if j.kind == "serve")
        results[label] = {
            "useful_serve_tokens": useful,
            "useful_serve_tokens_per_s": useful / counters["virtual_s"],
            "j_per_useful_serve_token":
                (counters["by_kind"].get("serve", {}).get("energy_j", 0.0)
                 / useful if useful else 0.0),
            "latency_p50_s": p50,
            "latency_p99_s": p99,
            # train rollback drops are identical in every run — the
            # recovery metric is about SERVING work only
            "serve_dropped_tokens": sum(j.dropped_total for j in jobs
                                        if j.kind == "serve"),
            "fleet": counters,
        }

    drop, mig = results["drop"], results["migrate"]
    part, int8 = results["partial"], results["int8"]
    dropped_base = drop["serve_dropped_tokens"]
    dropped_mig = mig["serve_dropped_tokens"]
    recovery = ((dropped_base - dropped_mig) / dropped_base
                if dropped_base else 1.0)
    results["recovery"] = recovery
    results["serve_token_gain"] = (
        mig["useful_serve_tokens"] / drop["useful_serve_tokens"]
        if drop["useful_serve_tokens"] else float("inf"))
    results["partial_token_gain"] = (
        part["useful_serve_tokens"] / mig["useful_serve_tokens"]
        if mig["useful_serve_tokens"] else float("inf"))
    results["int8_bytes_ratio"] = (
        int8["fleet"]["migration_bytes"] / mig["fleet"]["migration_bytes"]
        if mig["fleet"]["migration_bytes"] else float("inf"))
    results["scenario"] = {
        "nodes": n_nodes, "duration_s": duration,
        "serve_value": SERVE_VALUE,
        "serve_backoff_s": SERVE_BACKOFF_S,
        "train_backoff_s": TRAIN_BACKOFF_S,
        "budget_trace_w": [[t, w] for t, w in trace],
    }
    results["meta"] = bench_meta(config=results["scenario"])
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)

    for label, _ in ARMS:
        r = results[label]
        emit(f"migrate_{label}", r["fleet"]["busy_s"] * 1e6,
             f"{r['useful_serve_tokens']}tok"
             f"|{r['j_per_useful_serve_token']*1e3:.2f}mJ/tok"
             f"|p99={r['latency_p99_s']:.2f}s"
             f"|{r['serve_dropped_tokens']}dropped"
             f"|{r['fleet']['migrations']}migrations"
             f"|{r['fleet']['shed_slots']}shed")
    emit("migrate_recovery", 0.0, f"{recovery:.3f}")
    emit("migrate_serve_token_gain", 0.0,
         f"{results['serve_token_gain']:.3f}x")
    emit("migrate_partial_token_gain", 0.0,
         f"{results['partial_token_gain']:.3f}x")
    emit("migrate_int8_bytes_ratio", 0.0,
         f"{results['int8_bytes_ratio']:.3f}")

    # acceptance gates: the scenario must actually exercise every path,
    # lossless preemption must beat drop-and-restart on served tokens,
    # int8 must halve the wire bytes, and proportional sheds must serve
    # no fewer tokens than all-or-nothing drains at lower tail latency
    assert drop["fleet"]["preemptions"] >= 2, \
        "budget dips failed to exercise preemption"
    assert mig["fleet"]["migrations"] >= 1, \
        "no cross-node migration happened — scenario broken"
    assert mig["useful_serve_tokens"] >= drop["useful_serve_tokens"], (
        f"migration served fewer useful tokens "
        f"({mig['useful_serve_tokens']} < {drop['useful_serve_tokens']})")
    assert part["fleet"]["partial_drains"] >= 1, \
        "squeeze legs failed to exercise proportional preemption"
    assert part["useful_serve_tokens"] >= mig["useful_serve_tokens"], (
        f"partial drains served fewer useful tokens "
        f"({part['useful_serve_tokens']} < {mig['useful_serve_tokens']})")
    assert part["latency_p99_s"] < mig["latency_p99_s"], (
        f"partial drains did not improve p99 "
        f"({part['latency_p99_s']} >= {mig['latency_p99_s']})")
    assert 0.45 <= results["int8_bytes_ratio"] <= 0.55, (
        f"int8 payloads moved {results['int8_bytes_ratio']:.3f}x the raw "
        f"migration bytes (want ~0.5 +-10%)")
    if min_recovery is not None and recovery < min_recovery:
        raise SystemExit(
            f"migration regression: only {recovery:.3f} of the baseline's "
            f"dropped tokens recovered (threshold {min_recovery})")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--duration", type=float, default=40.0)
    ap.add_argument("--min-recovery", type=float, default=None,
                    help="fail loudly when migration recovers less than "
                         "this fraction of the tokens drop-and-restart "
                         "destroys (CI smoke)")
    ap.add_argument("--json-path", default="BENCH_migrate.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.nodes, args.duration, args.min_recovery, args.json_path)


if __name__ == "__main__":
    main()
