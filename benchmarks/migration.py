"""Migration benchmark: lossless serve preemption vs drop-and-restart.

The fleet scenario the portable-slot-state refactor exists for: a
facility budget that repeatedly dips below the fleet's floors (grid
events / thermal excursions), preempting EVERY job — including the
latency-sensitive serving jobs — then recovering.  The same mixed
queue (two high-value serve jobs, two background training jobs) runs
through ``repro.fleet.SimulatedCluster`` twice at the SAME budget
trace:

  drop      ServeJob(migrate=False) — the PR-3 baseline: a preempted
            serving stint destroys its in-flight batch; the tokens are
            refunded and regenerated after resume (double-paid work)
  migrate   ServeJob(migrate=True) — preemption drains every slot into
            a portable SlotSnapshot; the job re-queues WITH its
            snapshots and resumes on whichever node frees first, the
            cluster charging the snapshot transfer
            (bytes / interconnect BW) on the receiving node's clock

and reports per mode: USEFUL serve tokens (delivered once, never
redone), fleet tokens/s, modeled J per useful serve token, request
latency p50/p99 (virtual clock, wave completion), dropped vs migrated
tokens, and the migration count/bytes/seconds.  Everything runs on the
virtual clock — bit-deterministic, machine-independent.

Machine-readable results go to ``BENCH_migrate.json``.  Smoke gates
(CI): migration must recover at least ``--min-recovery`` (default 0.5)
of the tokens the baseline drops, and must not serve FEWER useful
tokens than the baseline.

  PYTHONPATH=src:. python benchmarks/migration.py \
      [--nodes 4] [--duration 40] [--min-recovery 0.5]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import emit
from repro.configs.registry import get_model_config
from repro.fleet import ServeJob, SimulatedCluster, TrainJob
from repro.hw.tpu import DEFAULT_SUPERCHIP

#: Token value of a serve token relative to a background train token in
#: the fleet objective (and the preemption order).
SERVE_VALUE = 4.0


def _jobs(n_nodes: int, migrate: bool) -> list:
    """Half serving (high value), half training (background)."""
    llama = get_model_config("llama3.2-3b")
    mamba = get_model_config("mamba2-370m")
    jobs = []
    for i in range(n_nodes):
        if i % 2 == 0:
            jobs.append(ServeJob(
                f"serve-{i}", llama, batch=32, prompt=1024, new_tokens=256,
                total_requests=10**9, decode_chunk=32, value=SERVE_VALUE,
                migrate=migrate, max_restarts=64))
        else:
            jobs.append(TrainJob(
                f"train-{i}", mamba if i % 4 == 3 else llama, batch=8,
                seq=512, total_steps=10**9, max_restarts=64))
    return jobs


def _budget_trace(n_nodes: int, duration: float) -> list:
    """Repeated deep dips below even one node's floor (everything
    preempts, serving included), with recovery legs in between — each
    cycle forces the serve jobs through a preempt/resume round and, on
    resume, onto different nodes (a migration)."""
    p = n_nodes * DEFAULT_SUPERCHIP.p_max
    legs, cycle = [], 0.25
    for k in range(int(1 / cycle)):
        legs.append((k * cycle, 0.75))
        legs.append((k * cycle + 0.15, 0.02))   # below any node's floor
        legs.append((k * cycle + 0.20, 0.75))
    return [(f * duration, frac * p) for f, frac in legs]


def _latency_pcts(jobs) -> tuple[float, float]:
    lats = sorted(l for j in jobs if j.kind == "serve"
                  for l in j.request_latencies)
    if not lats:
        return 0.0, 0.0
    p50 = lats[len(lats) // 2]
    p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
    return p50, p99


def run(n_nodes: int = 4, duration: float = 40.0,
        min_recovery: float | None = None,
        json_path: str = "BENCH_migrate.json") -> dict:
    trace = _budget_trace(n_nodes, duration)
    results: dict = {}
    for mode, label in ((False, "drop"), (True, "migrate")):
        jobs = _jobs(n_nodes, migrate=mode)
        cluster = SimulatedCluster(n_nodes=n_nodes,
                                   cabinet_size=max(n_nodes // 2, 1),
                                   policy="sensitivity")
        counters = cluster.run(jobs=jobs, budget=trace, until_s=duration)
        p50, p99 = _latency_pcts(jobs)
        useful = sum(j.emitted for j in jobs if j.kind == "serve")
        results[label] = {
            "useful_serve_tokens": useful,
            "useful_serve_tokens_per_s": useful / counters["virtual_s"],
            "j_per_useful_serve_token":
                (counters["by_kind"].get("serve", {}).get("energy_j", 0.0)
                 / useful if useful else 0.0),
            "latency_p50_s": p50,
            "latency_p99_s": p99,
            # train rollback drops are identical in both runs — the
            # recovery metric is about SERVING work only
            "serve_dropped_tokens": sum(j.dropped_total for j in jobs
                                        if j.kind == "serve"),
            "fleet": counters,
        }

    drop, mig = results["drop"], results["migrate"]
    dropped_base = drop["serve_dropped_tokens"]
    dropped_mig = mig["serve_dropped_tokens"]
    recovery = ((dropped_base - dropped_mig) / dropped_base
                if dropped_base else 1.0)
    results["recovery"] = recovery
    results["serve_token_gain"] = (
        mig["useful_serve_tokens"] / drop["useful_serve_tokens"]
        if drop["useful_serve_tokens"] else float("inf"))
    results["scenario"] = {
        "nodes": n_nodes, "duration_s": duration,
        "serve_value": SERVE_VALUE,
        "budget_trace_w": [[t, w] for t, w in trace],
    }
    with open(json_path, "w") as f:
        json.dump(results, f, indent=1)

    for label in ("drop", "migrate"):
        r = results[label]
        emit(f"migrate_{label}", r["fleet"]["busy_s"] * 1e6,
             f"{r['useful_serve_tokens']}tok"
             f"|{r['j_per_useful_serve_token']*1e3:.2f}mJ/tok"
             f"|p99={r['latency_p99_s']:.2f}s"
             f"|{r['serve_dropped_tokens']}dropped"
             f"|{r['fleet']['migrations']}migrations")
    emit("migrate_recovery", 0.0, f"{recovery:.3f}")
    emit("migrate_serve_token_gain", 0.0,
         f"{results['serve_token_gain']:.3f}x")

    # acceptance gates: the scenario must actually exercise both paths,
    # and lossless preemption must beat drop-and-restart on served
    # tokens under the same fleet budget
    assert drop["fleet"]["preemptions"] >= 2, \
        "budget dips failed to exercise preemption"
    assert mig["fleet"]["migrations"] >= 1, \
        "no cross-node migration happened — scenario broken"
    assert mig["useful_serve_tokens"] >= drop["useful_serve_tokens"], (
        f"migration served fewer useful tokens "
        f"({mig['useful_serve_tokens']} < {drop['useful_serve_tokens']})")
    if min_recovery is not None and recovery < min_recovery:
        raise SystemExit(
            f"migration regression: only {recovery:.3f} of the baseline's "
            f"dropped tokens recovered (threshold {min_recovery})")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--duration", type=float, default=40.0)
    ap.add_argument("--min-recovery", type=float, default=None,
                    help="fail loudly when migration recovers less than "
                         "this fraction of the tokens drop-and-restart "
                         "destroys (CI smoke)")
    ap.add_argument("--json-path", default="BENCH_migrate.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.nodes, args.duration, args.min_recovery, args.json_path)


if __name__ == "__main__":
    main()
