"""Paper Table 2: optimal caps per metric + energy/runtime deltas vs default.

Reproduces: (i) SED and ED agree for memory-bound/idle tasks but ED picks a
LOWER cap than SED for the compute-bound zgemm64 (paper: 600 vs 900 W);
(ii) aggregated, ED saves more energy at a larger runtime cost than SED
(paper: ~200 %/~203 % vs ~151 %/~90 % summed); (iii) the weighted
whole-application impact (beyond-paper extension of the 'ideal scenario'
sums)."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import (aggregate_table2, measure_sweep, table2,
                        weighted_application_impact)
from repro.models.lsms import paper_calibrated_tasks


def run() -> dict:
    table = measure_sweep(paper_calibrated_tasks())

    def compute():
        return table2(table)

    rows, us = timed(compute)
    by = {r.task: r for r in rows}
    # ED cap <= SED cap for the compute-bound gemm (paper: 600 vs 900)
    assert by["zgemm_ts64"].ed_cap < by["zgemm_ts64"].sed_cap, by
    # memory-bound agrees across metrics (paper: buildKKR 300/300)
    assert by["buildKKRMatrix"].ed_cap == by["buildKKRMatrix"].sed_cap

    agg = aggregate_table2(rows)
    # ED: more energy saved, more runtime paid (paper's headline contrast)
    assert (agg["ed_energy_savings_pct_sum"]
            > agg["sed_energy_savings_pct_sum"])
    assert (agg["ed_runtime_increase_pct_sum"]
            >= agg["sed_runtime_increase_pct_sum"])
    for k, v in agg.items():
        emit(f"table2_{k}", us, round(v, 1))
    wapp = weighted_application_impact(table)
    for k, v in wapp.items():
        emit(f"table2_{k}", us, round(v, 2))
    return {"rows": rows, "agg": agg, "weighted": wapp}


if __name__ == "__main__":
    run()
